//! Pure-Rust reference stage backend — the XLA-free compute path.
//!
//! A deliberately small next-token model with the *same stage contract*
//! as the AOT-compiled GPT stages (embed on the first global stage, one
//! Megatron-style MLP block per stage, softmax-xent head on the last), so
//! the whole coordinator — schedules, virtual chunks, collectives, tensor
//! parallelism, ZeRO-1 — can be exercised end-to-end without PJRT
//! artifacts.  The engine tests use it to prove schedule equivalence
//! (1F1B vs GPipe vs interleaved must walk the same loss trajectory) and
//! **tensor-parallel equivalence** (tp = 1/2/4 must walk the same
//! trajectory); gradients are validated against finite differences below,
//! for the dense and the sharded paths.
//!
//! Each stage block is the Megatron §II.B pattern, executed for real:
//!
//! ```text
//! h_r = tanh(x · W1_r + b1_r)        column-parallel first linear
//! y   = Σ_r h_r · W2_r  + b2         row-parallel second linear
//!       \__ all_reduce_sum __/        (forward: 1 all-reduce)
//! dx  = Σ_r dpre_r · W1_rᵀ           backward input grad: 1 all-reduce
//! ```
//!
//! The embedding is vocab-sharded (each shard contributes its owned token
//! rows, then one forward all-reduce); the head is a vocab-parallel
//! softmax-xent (all-reduce-max for stability, one packed all-reduce for
//! the (sum-exp, target-logit) statistics, one all-reduce for the input
//! gradient).  `tp = 1` ([`crate::collectives::TpComm::solo`]) turns every
//! all-reduce into a no-op, so the dense path IS the sharded path.
//!
//! All dense math runs on the cache-blocked, register-tiled kernels in
//! [`crate::runtime::kernels`] (bit-identical accumulation order to the
//! naive loops they replaced, so every equivalence test pins them too).
//!
//! Initialisation is keyed per *global* component (embedding, layer
//! index, head), never per stage or shard: each shard regenerates the
//! dense component stream and slices its own rows/columns, so any
//! partition of the same model — 1, 2, or `p·v` chunks, any `tp` —
//! materialises bit-identical parameter values.
//!
//! Replicated parameters: only the row-parallel bias `b2` is held by
//! every TP rank (Megatron holds norms/biases replicated the same way).
//! Its gradient is identical across shards by construction (it is a
//! function of the already-all-reduced `dy`); the engine still mean-
//! reduces it across the TP group before the optimizer step (see
//! [`BuiltinStage::replicated_span`]).

use crate::collectives::TpComm;
use crate::data::Rng64;
use crate::precision::{CastPolicy, Dtype};
use crate::runtime::kernels;

// ---------------------------------------------------------------------------
// GEMM dispatch: the fp32 policy takes the blocked kernels verbatim (the
// bitwise-pinned legacy path); bf16 routes through the bf16-in/f32-acc
// variants, which are idempotent over the stages' already-quantized
// storage (`kernels::bf16`).
// ---------------------------------------------------------------------------

fn mm(dt: Dtype, out: &mut [f32], a: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_acc(out, a, b, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_acc(out, a, b, t, k, n),
    }
}

fn mm_at(dt: Dtype, w: &mut [f32], a: &[f32], g: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_at_acc(w, a, g, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_at_acc(w, a, g, t, k, n),
    }
}

fn mm_bt(dt: Dtype, out: &mut [f32], g: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    match dt {
        Dtype::F32 => kernels::matmul_bt_acc(out, g, b, t, k, n),
        Dtype::Bf16 => kernels::bf16::matmul_bt_acc(out, g, b, t, k, n),
    }
}

/// Architecture + partition of one builtin bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuiltinSpec {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub seq: usize,
    pub mbs: usize,
    /// Global stages (= model blocks; one MLP block per stage).
    pub n_stages: usize,
}

impl BuiltinSpec {
    /// Parse an engine bundle name of the form `builtin:<model>-s<K>-mb<B>`
    /// (e.g. `builtin:tiny-s4-mb2`).  Returns `None` for artifact bundles.
    pub fn parse(bundle: &str) -> Option<Self> {
        let rest = bundle.strip_prefix("builtin:")?;
        let (model, rest) = rest.split_once("-s")?;
        let (stages, mbs) = rest.split_once("-mb")?;
        let n_stages: usize = stages.parse().ok()?;
        let mbs: usize = mbs.parse().ok()?;
        if n_stages == 0 || mbs == 0 {
            return None;
        }
        let (vocab, hidden, seq) = match model {
            "tiny" => (64, 16, 8),
            "mini" => (128, 32, 16),
            _ => return None,
        };
        Some(Self { name: model.to_string(), vocab, hidden, seq, mbs, n_stages })
    }

    pub fn embed_params(&self) -> usize {
        self.vocab * self.hidden
    }

    /// One block: W1 (d×d) + b1 (d) + W2 (d×d) + b2 (d).
    pub fn layer_params(&self) -> usize {
        2 * self.hidden * self.hidden + 2 * self.hidden
    }

    pub fn head_params(&self) -> usize {
        self.hidden * self.vocab + self.vocab
    }

    pub fn total_params(&self) -> usize {
        self.embed_params() + self.n_stages * self.layer_params() + self.head_params()
    }

    /// Parameters held by global stage `g` (embed on 0, head on last).
    pub fn stage_params(&self, g: usize) -> usize {
        let mut n = self.layer_params();
        if g == 0 {
            n += self.embed_params();
        }
        if g == self.n_stages - 1 {
            n += self.head_params();
        }
        n
    }

    // ---- tensor-parallel shard accounting ----

    /// TP degree `tp` is executable iff it slices both sharded dims.
    pub fn tp_ok(&self, tp: usize) -> bool {
        tp >= 1 && self.hidden % tp == 0 && self.vocab % tp == 0
    }

    /// Embedding rows held by one shard: (vocab/tp) × d.
    pub fn shard_embed_params(&self, tp: usize) -> usize {
        (self.vocab / tp) * self.hidden
    }

    /// Block parameters held by one shard: W1 cols + b1 slice + W2 rows +
    /// the replicated b2.
    pub fn shard_layer_params(&self, tp: usize) -> usize {
        let d = self.hidden;
        let f = d / tp;
        d * f + f + f * d + d
    }

    /// Head parameters held by one shard: (d × vocab/tp) + vocab/tp.
    pub fn shard_head_params(&self, tp: usize) -> usize {
        let vs = self.vocab / tp;
        self.hidden * vs + vs
    }

    /// Parameters held by shard `tp_rank` of global stage `g`.
    pub fn shard_stage_params(&self, g: usize, tp: usize) -> usize {
        let mut n = self.shard_layer_params(tp);
        if g == 0 {
            n += self.shard_embed_params(tp);
        }
        if g == self.n_stages - 1 {
            n += self.shard_head_params(tp);
        }
        n
    }
}

/// One global stage of the builtin model (optional embed, one MLP block,
/// optional vocab-parallel head), or one TP shard of it: `tp = 1`,
/// `tp_rank = 0` is the dense case.
#[derive(Debug, Clone)]
pub struct BuiltinStage {
    pub spec: BuiltinSpec,
    /// Global stage index (= global block index).
    pub stage: usize,
    /// Tensor-parallel group size this shard belongs to.
    pub tp: usize,
    /// This shard's rank within the TP group.
    pub tp_rank: usize,
    /// Numeric cast points (`CastPolicy::fp32()` = the legacy path,
    /// every cast a no-op).  Under bf16 the stage stores parameters,
    /// activations and per-micro-batch gradients on the bf16 grid and
    /// runs every GEMM bf16-in/f32-accumulate; the collective wire dtype
    /// is carried by the [`TpComm`] the engine hands each call.
    pub policy: CastPolicy,
}

/// Per-component init streams keyed by (run seed, global component id) so
/// every partition of the model draws identical values.
fn component_rng(seed: u64, salt: u64) -> Rng64 {
    Rng64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt ^ 0x5EED_CAFE)
}

/// Offsets of the shard-local parameter segments in the flat vector.
struct Lay {
    w1: usize,
    b1: usize,
    w2: usize,
    b2: usize,
    hw: usize,
    hb: usize,
}

impl BuiltinStage {
    /// Dense (tp = 1) stage.
    pub fn dense(spec: BuiltinSpec, stage: usize) -> Self {
        Self { spec, stage, tp: 1, tp_rank: 0, policy: CastPolicy::fp32() }
    }

    /// TP shard `tp_rank`/`tp` of a stage.
    pub fn sharded(spec: BuiltinSpec, stage: usize, tp: usize, tp_rank: usize) -> Self {
        assert!(spec.tp_ok(tp), "tp {tp} does not slice hidden/vocab");
        assert!(tp_rank < tp);
        Self { spec, stage, tp, tp_rank, policy: CastPolicy::fp32() }
    }

    /// The same stage under a different cast policy (builder-style; the
    /// engine sets the bundle-wide policy once at construction).
    pub fn with_policy(mut self, policy: CastPolicy) -> Self {
        self.policy = policy;
        self
    }

    fn d(&self) -> usize {
        self.spec.hidden
    }

    fn v(&self) -> usize {
        self.spec.vocab
    }

    /// Sharded feature width d/tp (column width of W1, row count of W2).
    fn f(&self) -> usize {
        self.spec.hidden / self.tp
    }

    /// Sharded vocab width vocab/tp.
    fn vs(&self) -> usize {
        self.spec.vocab / self.tp
    }

    /// First vocab id owned by this shard.
    fn vlo(&self) -> usize {
        self.tp_rank * self.vs()
    }

    /// First hidden feature owned by this shard.
    fn flo(&self) -> usize {
        self.tp_rank * self.f()
    }

    pub fn has_embed(&self) -> bool {
        self.stage == 0
    }

    pub fn has_head(&self) -> bool {
        self.stage == self.spec.n_stages - 1
    }

    pub fn param_count(&self) -> usize {
        self.spec.shard_stage_params(self.stage, self.tp)
    }

    /// Span of the TP-replicated parameters (the row-parallel bias b2) in
    /// this shard's flat vector — what the engine mean-reduces across the
    /// TP group before the optimizer step.
    pub fn replicated_span(&self) -> (usize, usize) {
        let l = self.lay();
        (l.b2, l.b2 + self.d())
    }

    fn lay(&self) -> Lay {
        let d = self.d();
        let f = self.f();
        let embed = if self.has_embed() { self.vs() * d } else { 0 };
        let w1 = embed;
        let b1 = w1 + d * f;
        let w2 = b1 + f;
        let b2 = w2 + f * d;
        let hw = b2 + d;
        let hb = hw + if self.has_head() { d * self.vs() } else { 0 };
        Lay { w1, b1, w2, b2, hw, hb }
    }

    /// Deterministic, partition- and shard-invariant init of this shard's
    /// flat parameter vector: regenerate each dense component stream and
    /// slice this shard's rows/columns.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let d = self.d();
        let v = self.v();
        let f = self.f();
        let vs = self.vs();
        let scale = 1.0 / (d as f64).sqrt();
        let mut out = Vec::with_capacity(self.param_count());
        if self.has_embed() {
            let mut rng = component_rng(seed, 0xE0_BED);
            let dense: Vec<f32> = (0..v * d).map(|_| (rng.normal() * 0.5) as f32).collect();
            out.extend_from_slice(&dense[self.vlo() * d..(self.vlo() + vs) * d]);
        }
        let mut rng = component_rng(seed, 0x1A7E5 + self.stage as u64);
        let w1: Vec<f32> = (0..d * d).map(|_| (rng.normal() * scale) as f32).collect();
        let w2: Vec<f32> = (0..d * d).map(|_| (rng.normal() * scale) as f32).collect();
        // column shard of W1: every input row i, cols [flo, flo + f)
        for i in 0..d {
            let row = i * d + self.flo();
            out.extend_from_slice(&w1[row..row + f]);
        }
        out.extend(std::iter::repeat(0.0f32).take(f)); // b1 shard
        // row shard of W2: rows [flo, flo + f), all d cols
        out.extend_from_slice(&w2[self.flo() * d..(self.flo() + f) * d]);
        out.extend(std::iter::repeat(0.0f32).take(d)); // b2 (replicated)
        if self.has_head() {
            let mut rng = component_rng(seed, 0xD_EAD);
            let dense: Vec<f32> = (0..d * v).map(|_| (rng.normal() * scale) as f32).collect();
            // column shard of the head: row i, vocab cols [vlo, vlo + vs)
            for i in 0..d {
                let row = i * v + self.vlo();
                out.extend_from_slice(&dense[row..row + vs]);
            }
            out.extend(std::iter::repeat(0.0f32).take(vs)); // head bias shard
        }
        debug_assert_eq!(out.len(), self.param_count());
        // parameter storage cast: constrain the working copy to the grid
        // (no-op under fp32); the quantization commutes with the shard
        // slicing above, so shard inits stay slices of the dense init
        self.policy.param.quantize_slice(&mut out);
        out
    }

    /// Vocab-sharded embedding forward: each shard contributes its owned
    /// token rows, one all-reduce assembles the full activation.
    fn embed(&self, comm: &TpComm, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        let mut x = vec![0.0f32; tokens.len() * d];
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vlo && tok < vlo + vs {
                let row = (tok - vlo) * d;
                x[t * d..(t + 1) * d].copy_from_slice(&params[row..row + d]);
            }
        }
        comm.all_reduce_sum(&mut x);
        x
    }

    /// Embedding backward: scatter `dx` rows into this shard's owned rows
    /// of the table gradient.  No communication (dx is already full).
    fn embed_bwd(&self, gparams: &mut [f32], tokens: &[i32], dx: &[f32]) {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        for (t, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= vlo && tok < vlo + vs {
                let row = (tok - vlo) * d;
                for (g, &v) in gparams[row..row + d].iter_mut().zip(&dx[t * d..(t + 1) * d]) {
                    *g += v;
                }
            }
        }
    }

    /// Column-parallel first linear + tanh: `h_r = tanh(x W1_r + b1_r)`,
    /// T × f.  Shard-local (no communication); blocked GEMM kernel.
    fn first_linear(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let l = self.lay();
        let (w1, b1) = (&params[l.w1..l.w1 + d * f], &params[l.b1..l.b1 + f]);
        let t_count = x.len() / d;
        let mut h = vec![0.0f32; t_count * f];
        for t in 0..t_count {
            h[t * f..(t + 1) * f].copy_from_slice(b1);
        }
        mm(self.policy.activation, &mut h, x, w1, t_count, d, f);
        for o in h.iter_mut() {
            *o = o.tanh();
        }
        // activation storage cast (the recomputing backward re-derives
        // the identical quantized h, so fwd and bwd agree)
        self.policy.activation.quantize_slice(&mut h);
        h
    }

    /// Row-parallel second linear: `y = all_reduce(h_r W2_r) + b2`,
    /// T × d.  One all-reduce (the Megatron forward `g`).
    fn second_linear(&self, comm: &TpComm, params: &[f32], h: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let l = self.lay();
        let (w2, b2) = (&params[l.w2..l.w2 + f * d], &params[l.b2..l.b2 + d]);
        let t_count = h.len() / f;
        let mut y = vec![0.0f32; t_count * d];
        mm(self.policy.activation, &mut y, h, w2, t_count, f, d);
        comm.all_reduce_sum(&mut y);
        for t in 0..t_count {
            for (o, &bv) in y[t * d..(t + 1) * d].iter_mut().zip(b2) {
                *o += bv;
            }
        }
        // activation storage cast on the block output
        self.policy.activation.quantize_slice(&mut y);
        y
    }

    /// Block forward: column-parallel linear -> tanh -> row-parallel
    /// linear (1 all-reduce).
    fn block_fwd(&self, comm: &TpComm, params: &[f32], x: &[f32]) -> Vec<f32> {
        let h = self.first_linear(params, x);
        self.second_linear(comm, params, &h)
    }

    /// Block backward given the stage input `x` and upstream grad `dy`
    /// (recomputes the shard-local forward — checkpointing semantics).
    /// Writes parameter grads into `g` and returns the full `dx`
    /// (all-reduced across the TP group: the Megatron backward `f`).
    fn block_bwd(&self, comm: &TpComm, params: &[f32], g: &mut [f32], x: &[f32], dy: &[f32]) -> Vec<f32> {
        let d = self.d();
        let f = self.f();
        let l = self.lay();
        let h = self.first_linear(params, x); // recompute
        let t_count = x.len() / d;
        let act = self.policy.activation;
        let (w1, w2) = (&params[l.w1..l.w1 + d * f], &params[l.w2..l.w2 + f * d]);
        // b2 grad (replicated parameter, dy already full); bias grads
        // accumulate in f32 on both policies
        kernels::col_sum_acc(&mut g[l.b2..l.b2 + d], dy, t_count, d);
        // dW2_r += h_rᵀ dy ;  dh_r = dy W2_rᵀ
        mm_at(act, &mut g[l.w2..l.w2 + f * d], &h, dy, t_count, f, d);
        let mut dh = vec![0.0f32; t_count * f];
        mm_bt(act, &mut dh, dy, w2, t_count, f, d);
        // through tanh: dpre = dh ⊙ (1 - h²)
        for (dp, &hv) in dh.iter_mut().zip(&h) {
            *dp *= 1.0 - hv * hv;
        }
        // gradient-activation storage cast before dpre feeds two GEMMs
        act.quantize_slice(&mut dh);
        kernels::col_sum_acc(&mut g[l.b1..l.b1 + f], &dh, t_count, f);
        // dW1_r += xᵀ dpre ;  dx_partial = dpre W1_rᵀ
        mm_at(act, &mut g[l.w1..l.w1 + d * f], x, &dh, t_count, d, f);
        let mut dx = vec![0.0f32; x.len()];
        mm_bt(act, &mut dx, &dh, w1, t_count, d, f);
        comm.all_reduce_sum(&mut dx);
        // gradient-activation cast on the dx handed upstream
        act.quantize_slice(&mut dx);
        dx
    }

    /// Vocab-parallel softmax-xent head: loss + gradient into the block
    /// output `y`.  Three reductions: all-reduce-max (stability), one
    /// packed all-reduce-sum for the per-token (sum-exp, target-logit)
    /// statistics, one all-reduce-sum for the input gradient `dy`.
    fn head_bwd(
        &self,
        comm: &TpComm,
        params: &[f32],
        gparams: &mut [f32],
        y: &[f32],
        targets: &[i32],
    ) -> (Vec<f32>, f32) {
        let d = self.d();
        let vs = self.vs();
        let vlo = self.vlo();
        let l = self.lay();
        let wh = &params[l.hw..l.hw + d * vs];
        let t_count = y.len() / d;
        let inv_t = 1.0 / t_count as f32;

        // local logit shard, T × vs (blocked GEMM); logits stay f32 —
        // the softmax statistics path is the numerically fragile one
        let mut logits = vec![0.0f32; t_count * vs];
        for t in 0..t_count {
            logits[t * vs..(t + 1) * vs].copy_from_slice(&params[l.hb..l.hb + vs]);
        }
        mm(self.policy.activation, &mut logits, y, wh, t_count, d, vs);
        // global per-token max for the stable softmax
        let mut mx: Vec<f32> = (0..t_count)
            .map(|t| {
                logits[t * vs..(t + 1) * vs]
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        comm.all_reduce_max(&mut mx);
        // packed statistics: stats[t] = Σ_u exp(l - M), stats[T + t] = the
        // shifted target logit (owner contributes, others add 0).
        // `logits` is exponentiated in place (softmax numerators).
        let mut stats = vec![0.0f32; 2 * t_count];
        for t in 0..t_count {
            let tgt = targets[t] as usize;
            let lo = &mut logits[t * vs..(t + 1) * vs];
            if tgt >= vlo && tgt < vlo + vs {
                stats[t_count + t] = lo[tgt - vlo] - mx[t];
            }
            let mut z = 0.0f32;
            for v in lo.iter_mut() {
                *v = (*v - mx[t]).exp();
                z += *v;
            }
            stats[t] = z;
        }
        comm.all_reduce_sum(&mut stats);
        let mut loss = 0.0f32;
        for t in 0..t_count {
            loss -= (stats[t_count + t] - stats[t].max(1e-30).ln()) * inv_t;
        }
        // dlogits = (softmax - onehot) / T ;  dy = all_reduce(dlogits Wᵀ)
        for t in 0..t_count {
            let z = stats[t].max(1e-30);
            let tgt = targets[t] as usize;
            let lo = &mut logits[t * vs..(t + 1) * vs];
            for (u, v) in lo.iter_mut().enumerate() {
                let one = f32::from(tgt >= vlo && tgt < vlo + vs && u == tgt - vlo);
                *v = (*v / z - one) * inv_t;
            }
        }
        kernels::col_sum_acc(&mut gparams[l.hb..l.hb + vs], &logits, t_count, vs);
        mm_at(self.policy.activation, &mut gparams[l.hw..l.hw + d * vs], y, &logits, t_count, d, vs);
        let mut dy = vec![0.0f32; y.len()];
        mm_bt(self.policy.activation, &mut dy, &logits, wh, t_count, d, vs);
        comm.all_reduce_sum(&mut dy);
        // gradient-activation cast on the loss gradient fed to the block
        self.policy.activation.quantize_slice(&mut dy);
        (dy, loss)
    }

    // ---- the stage entry points the worker drives ----

    /// First-stage forward: tokens -> activation.
    pub fn fwd_first(&self, comm: &TpComm, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        let x = self.embed(comm, params, tokens);
        self.block_fwd(comm, params, &x)
    }

    /// Middle-stage forward: activation -> activation.
    pub fn fwd_mid(&self, comm: &TpComm, params: &[f32], x: &[f32]) -> Vec<f32> {
        self.block_fwd(comm, params, x)
    }

    /// Last-stage backward: (stage input, targets) -> (gparams, gx, loss).
    pub fn bwd_last(
        &self,
        comm: &TpComm,
        params: &[f32],
        x: &[f32],
        targets: &[i32],
    ) -> (Vec<f32>, Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let y = self.block_fwd(comm, params, x);
        let (dy, loss) = self.head_bwd(comm, params, &mut g, &y, targets);
        let dx = self.block_bwd(comm, params, &mut g, x, &dy);
        self.policy.grad.quantize_slice(&mut g);
        (g, dx, loss)
    }

    /// Middle-stage backward: (stage input, upstream grad) -> (gparams, gx).
    pub fn bwd_mid(&self, comm: &TpComm, params: &[f32], x: &[f32], gy: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut g = vec![0.0f32; params.len()];
        let dx = self.block_bwd(comm, params, &mut g, x, gy);
        self.policy.grad.quantize_slice(&mut g);
        (g, dx)
    }

    /// First-stage backward: (tokens, upstream grad) -> gparams.
    pub fn bwd_first(&self, comm: &TpComm, params: &[f32], tokens: &[i32], gy: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(comm, params, tokens);
        let dx = self.block_bwd(comm, params, &mut g, &x, gy);
        self.embed_bwd(&mut g, tokens, &dx);
        self.policy.grad.quantize_slice(&mut g);
        g
    }

    /// Fused single-stage backward (K = 1): (tokens, targets) ->
    /// (gparams, loss).
    pub fn bwd_single(
        &self,
        comm: &TpComm,
        params: &[f32],
        tokens: &[i32],
        targets: &[i32],
    ) -> (Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(comm, params, tokens);
        let y = self.block_fwd(comm, params, &x);
        let (dy, loss) = self.head_bwd(comm, params, &mut g, &y, targets);
        let dx = self.block_bwd(comm, params, &mut g, &x, &dy);
        self.embed_bwd(&mut g, tokens, &dx);
        self.policy.grad.quantize_slice(&mut g);
        (g, loss)
    }
}

/// Extract the shard `(tp, tp_rank)` slice of a *dense* flat vector for
/// stage `g` — the mapping [`BuiltinStage::init`] applies to each dense
/// component stream.  Works for parameter vectors and (because gradients
/// share the layout) gradient vectors; the tests use it to pin sharded
/// results to slices of the dense ones.
pub fn extract_shard(spec: &BuiltinSpec, g: usize, tp: usize, tp_rank: usize, dense: &[f32]) -> Vec<f32> {
    assert_eq!(dense.len(), spec.stage_params(g));
    let shard = BuiltinStage::sharded(spec.clone(), g, tp, tp_rank);
    let d = spec.hidden;
    let v = spec.vocab;
    let f = d / tp;
    let vs = v / tp;
    let flo = tp_rank * f;
    let vlo = tp_rank * vs;
    let mut out = Vec::with_capacity(shard.param_count());
    let mut off = 0;
    if g == 0 {
        out.extend_from_slice(&dense[vlo * d..(vlo + vs) * d]);
        off += v * d;
    }
    // W1 columns
    for i in 0..d {
        let row = off + i * d + flo;
        out.extend_from_slice(&dense[row..row + f]);
    }
    off += d * d;
    // b1 slice
    out.extend_from_slice(&dense[off + flo..off + flo + f]);
    off += d;
    // W2 rows
    out.extend_from_slice(&dense[off + flo * d..off + (flo + f) * d]);
    off += d * d;
    // b2 replicated
    out.extend_from_slice(&dense[off..off + d]);
    off += d;
    if g == spec.n_stages - 1 {
        // head W columns
        for i in 0..d {
            let row = off + i * v + vlo;
            out.extend_from_slice(&dense[row..row + vs]);
        }
        off += d * v;
        // head bias slice
        out.extend_from_slice(&dense[off + vlo..off + vlo + vs]);
        off += v;
    }
    assert_eq!(off, dense.len());
    assert_eq!(out.len(), shard.param_count());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::{Group, SubGroup};
    use std::sync::Arc;
    use std::thread;

    fn spec(k: usize) -> BuiltinSpec {
        BuiltinSpec::parse(&format!("builtin:tiny-s{k}-mb2")).unwrap()
    }

    fn stage(sp: &BuiltinSpec, g: usize) -> BuiltinStage {
        BuiltinStage::dense(sp.clone(), g)
    }

    fn solo() -> TpComm {
        TpComm::solo()
    }

    fn test_tokens(sp: &BuiltinSpec, mul: usize, add: usize) -> (Vec<i32>, Vec<i32>) {
        let t = sp.mbs * sp.seq;
        let tokens: Vec<i32> = (0..t).map(|i| (i * mul % sp.vocab) as i32).collect();
        let targets: Vec<i32> = (0..t).map(|i| ((i * mul + add) % sp.vocab) as i32).collect();
        (tokens, targets)
    }

    /// Run `f(tp_rank, comm)` on `tp` threads sharing one TP group.
    fn run_tp<T, F>(tp: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize, TpComm) -> T + Send + Sync + 'static,
    {
        let world = Group::new(tp);
        let sub = SubGroup::new(&world, (0..tp).collect(), 0);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..tp)
            .map(|r| {
                let comm = TpComm::new(sub.clone(), r);
                let f = f.clone();
                thread::spawn(move || f(r, comm))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn parse_bundle_names() {
        let sp = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
        assert_eq!((sp.n_stages, sp.mbs, sp.hidden), (4, 2, 16));
        assert!(BuiltinSpec::parse("tiny-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:nope-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:tiny-s0-mb2").is_none());
    }

    #[test]
    fn stage_params_sum_to_total() {
        for k in [1usize, 2, 4] {
            let sp = spec(k);
            let sum: usize = (0..k).map(|g| sp.stage_params(g)).sum();
            assert_eq!(sum, sp.total_params());
            for g in 0..k {
                assert_eq!(stage(&sp, g).init(7).len(), sp.stage_params(g));
            }
        }
    }

    #[test]
    fn shard_params_account_for_replication() {
        // shards hold 1/tp of everything except the replicated b2
        for k in [1usize, 2, 4] {
            let sp = spec(k);
            for tp in [2usize, 4, 8] {
                assert!(sp.tp_ok(tp));
                for g in 0..k {
                    let dense = sp.stage_params(g);
                    let shard = sp.shard_stage_params(g, tp);
                    // dense splits exactly except b2 (d) replicated per shard
                    let replicated_extra = sp.hidden - sp.hidden / tp;
                    assert_eq!(shard, dense / tp + replicated_extra, "k={k} tp={tp} g={g}");
                    let st = BuiltinStage::sharded(sp.clone(), g, tp, tp - 1);
                    assert_eq!(st.init(7).len(), shard);
                }
            }
        }
        assert!(!spec(1).tp_ok(3));
    }

    #[test]
    fn init_is_partition_invariant() {
        // block 1's W1 must be identical whether the model is cut into 2
        // or 4 stages (global component keys)
        let s2 = stage(&spec(2), 1);
        let s4 = stage(&spec(4), 1);
        let p2 = s2.init(42);
        let p4 = s4.init(42);
        let d = 16;
        assert_eq!(&p2[..d * d], &p4[..d * d]);
    }

    #[test]
    fn init_is_shard_invariant() {
        // each shard's init is exactly its slice of the dense init
        for k in [1usize, 2] {
            let sp = spec(k);
            for g in 0..k {
                let dense = stage(&sp, g).init(42);
                for tp in [2usize, 4] {
                    for r in 0..tp {
                        let st = BuiltinStage::sharded(sp.clone(), g, tp, r);
                        assert_eq!(
                            st.init(42),
                            extract_shard(&sp, g, tp, r, &dense),
                            "k={k} g={g} tp={tp} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn gradcheck_single_stage() {
        // finite differences on the fused dense path (the multi-stage
        // paths are compositions of the same block/head/embed pieces)
        let sp = spec(1);
        let st = stage(&sp, 0);
        let comm = solo();
        let mut params = st.init(3);
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let (g, _) = st.bwd_single(&comm, &params, &tokens, &targets);
        let eps = 1e-3f32;
        let mut worst = 0.0f32;
        // embed, W1, b1, W2, b2, head W, head b probes
        let d = sp.hidden;
        let e = sp.embed_params();
        for idx in [
            0usize,
            100,
            e + 3,                       // W1
            e + d * d + 2,               // b1
            e + d * d + d + 11,          // W2
            e + 2 * d * d + d + 5,       // b2
            e + sp.layer_params() + 17,  // head W
            params.len() - 1,            // head b
        ] {
            let orig = params[idx];
            params[idx] = orig + eps;
            let (_, lp) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig - eps;
            let (_, lm) = st.bwd_single(&comm, &params, &tokens, &targets);
            params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            worst = worst.max((fd - g[idx]).abs());
        }
        assert!(worst < 2e-3, "finite-diff mismatch: {worst}");
    }

    #[test]
    fn sharded_matches_dense_tp2_tp4() {
        // forward activations, loss and every shard gradient must equal
        // the dense run (up to fp association order)
        let sp = spec(1);
        let st_dense = stage(&sp, 0);
        let comm = solo();
        let pd = st_dense.init(11);
        let (tokens, targets) = test_tokens(&sp, 5, 2);
        let y_dense = st_dense.fwd_first(&comm, &pd, &tokens);
        let (gd, loss_dense) = st_dense.bwd_single(&comm, &pd, &tokens, &targets);

        for tp in [2usize, 4] {
            let sp2 = sp.clone();
            let tk = tokens.clone();
            let tg = targets.clone();
            let results = run_tp(tp, move |r, comm| {
                let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
                let p = st.init(11);
                let y = st.fwd_first(&comm, &p, &tk);
                let (g, loss) = st.bwd_single(&comm, &p, &tk, &tg);
                (y, g, loss)
            });
            for (r, (y, g, loss)) in results.into_iter().enumerate() {
                assert!(
                    (loss - loss_dense).abs() < 1e-4,
                    "tp={tp} r={r}: loss {loss} vs {loss_dense}"
                );
                for (a, b) in y.iter().zip(&y_dense) {
                    assert!((a - b).abs() < 1e-4, "tp={tp} r={r} fwd: {a} vs {b}");
                }
                let want = extract_shard(&sp, 0, tp, r, &gd);
                assert_eq!(g.len(), want.len());
                for (i, (a, b)) in g.iter().zip(&want).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-4,
                        "tp={tp} r={r} grad[{i}]: {a} vs {b}"
                    );
                }
            }
        }
    }

    /// Sharded 2-stage chain: fwd_first -> bwd_last -> bwd_first, with the
    /// loss recomputed under parameter perturbations for finite
    /// differencing.  Returns (loss, g0 shards, g1 shards).
    #[allow(clippy::type_complexity)]
    fn tp_chain(
        sp: &BuiltinSpec,
        tp: usize,
        p0: Vec<Vec<f32>>,
        p1: Vec<Vec<f32>>,
        tokens: Vec<i32>,
        targets: Vec<i32>,
    ) -> (f32, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let sp = sp.clone();
        let results = run_tp(tp, move |r, comm| {
            let s0 = BuiltinStage::sharded(sp.clone(), 0, tp, r);
            let s1 = BuiltinStage::sharded(sp.clone(), 1, tp, r);
            let y = s0.fwd_first(&comm, &p0[r], &tokens);
            let (g1, gx, loss) = s1.bwd_last(&comm, &p1[r], &y, &targets);
            let g0 = s0.bwd_first(&comm, &p0[r], &tokens, &gx);
            (loss, g0, g1)
        });
        let loss = results[0].0;
        let g0 = results.iter().map(|r| r.1.clone()).collect();
        let g1 = results.iter().map(|r| r.2.clone()).collect();
        (loss, g0, g1)
    }

    #[test]
    fn gradcheck_sharded_paths() {
        // finite differences THROUGH the communicating sharded stages at
        // tp ∈ {2, 4}: perturb one element of one shard, re-run the whole
        // TP group, compare the loss slope to the analytic shard gradient.
        // Probes cover every sharded component: vocab-sharded embed,
        // column-parallel W1/b1, row-parallel W2, replicated b2,
        // vocab-parallel head W/bias.
        let sp = spec(2);
        let (tokens, targets) = test_tokens(&sp, 5, 1);
        for tp in [2usize, 4] {
            let shards0: Vec<Vec<f32>> =
                (0..tp).map(|r| BuiltinStage::sharded(sp.clone(), 0, tp, r).init(9)).collect();
            let shards1: Vec<Vec<f32>> =
                (0..tp).map(|r| BuiltinStage::sharded(sp.clone(), 1, tp, r).init(9)).collect();
            let (_, g0, g1) = tp_chain(
                &sp,
                tp,
                shards0.clone(),
                shards1.clone(),
                tokens.clone(),
                targets.clone(),
            );

            let d = sp.hidden;
            let f = d / tp;
            let vs = sp.vocab / tp;
            let embed = vs * d;
            // probes: (stage, rank, shard index, replicated).  b2 is
            // REPLICATED — the analytic gradient treats it as one shared
            // parameter (every shard computes the identical db2), so its
            // finite-diff probe must move every shard's copy together.
            let l1 = sp.shard_layer_params(tp);
            let probes = [
                (0usize, 0usize, 3usize, false),            // embed row
                (0, tp - 1, embed + 1, false),              // W1 column
                (0, 0, embed + d * f + 1, false),           // b1 slice
                (0, tp - 1, embed + d * f + f + 2, false),  // W2 row
                (0, 0, embed + d * f + f + f * d + 3, true), // b2 (replicated)
                (1, 0, 1, false),                           // W1
                (1, tp - 1, l1 - 2, true),                  // b2 (replicated)
                (1, 0, l1 + 4, false),                      // head W
                (1, tp - 1, l1 + d * vs + 1, false),        // head b
            ];
            let eps = 1e-3f32;
            let mut worst = 0.0f32;
            for &(stage_idx, r, idx, replicated) in probes.iter() {
                let perturb = |delta: f32| -> f32 {
                    let mut s0 = shards0.clone();
                    let mut s1 = shards1.clone();
                    let bumped = if stage_idx == 0 { &mut s0 } else { &mut s1 };
                    if replicated {
                        for shard in bumped.iter_mut() {
                            shard[idx] += delta;
                        }
                    } else {
                        bumped[r][idx] += delta;
                    }
                    tp_chain(&sp, tp, s0, s1, tokens.clone(), targets.clone()).0
                };
                let fd = (perturb(eps) - perturb(-eps)) / (2.0 * eps);
                let analytic = if stage_idx == 0 { g0[r][idx] } else { g1[r][idx] };
                worst = worst.max((fd - analytic).abs());
            }
            assert!(worst < 2e-3, "tp={tp}: finite-diff mismatch {worst}");
        }
    }

    #[test]
    fn pipeline_composition_matches_fused() {
        // chaining stage entry points across a 2-stage cut must match a
        // finite-diff through the composed forward wrt a stage-0 weight
        let sp = spec(2);
        let s0 = stage(&sp, 0);
        let s1 = stage(&sp, 1);
        let comm = solo();
        let p0 = s0.init(9);
        let p1 = s1.init(9);
        let (tokens, targets) = test_tokens(&sp, 5, 1);

        let y0 = s0.fwd_first(&comm, &p0, &tokens);
        let (g1, gx, loss) = s1.bwd_last(&comm, &p1, &y0, &targets);
        let g0 = s0.bwd_first(&comm, &p0, &tokens, &gx);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(g0.iter().any(|&x| x != 0.0));
        assert!(g1.iter().any(|&x| x != 0.0));

        let fwd_loss = |p0: &[f32]| -> f32 {
            let y0 = s0.fwd_first(&comm, p0, &tokens);
            let (_, _, l) = s1.bwd_last(&comm, &p1, &y0, &targets);
            l
        };
        let idx = sp.embed_params() + 3; // a W1 element
        let eps = 1e-3f32;
        let mut pp = p0.clone();
        pp[idx] += eps;
        let lp = fwd_loss(&pp);
        pp[idx] = p0[idx] - eps;
        let lm = fwd_loss(&pp);
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - g0[idx]).abs() < 2e-3, "fd {fd} vs analytic {}", g0[idx]);
    }

    #[test]
    fn bf16_policy_stays_on_grid_and_tracks_fp32() {
        // the bf16 cast points: init / grads constrained to the grid,
        // loss and gradients tracking the fp32 stage within bf16 noise
        let sp = spec(1);
        let comm = solo();
        let fp = stage(&sp, 0);
        let bf = stage(&sp, 0).with_policy(CastPolicy::bf16());
        let (tokens, targets) = test_tokens(&sp, 7, 1);
        let p32 = fp.init(3);
        let p16 = bf.init(3);
        assert_eq!(p16.len(), p32.len());
        for (i, (a, b)) in p16.iter().zip(&p32).enumerate() {
            assert_eq!(
                a.to_bits(),
                Dtype::Bf16.quantize(*b).to_bits(),
                "init[{i}] must be the quantized fp32 init"
            );
        }
        let y32 = fp.fwd_first(&comm, &p32, &tokens);
        let y16 = bf.fwd_first(&comm, &p16, &tokens);
        for (i, (a, b)) in y16.iter().zip(&y32).enumerate() {
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "act[{i}] off grid");
            assert!((a - b).abs() < 0.05 * b.abs() + 0.05, "act[{i}]: {a} vs {b}");
        }
        let (g32, l32) = fp.bwd_single(&comm, &p32, &tokens, &targets);
        let (g16, l16) = bf.bwd_single(&comm, &p16, &tokens, &targets);
        assert!(l16.is_finite());
        assert!((l16 - l32).abs() < 0.05 * l32.abs().max(1.0), "loss {l16} vs {l32}");
        for (i, (a, b)) in g16.iter().zip(&g32).enumerate() {
            assert_eq!(a.to_bits(), Dtype::Bf16.quantize(*a).to_bits(), "grad[{i}] off grid");
            assert!((a - b).abs() < 0.05 * b.abs() + 5e-3, "grad[{i}]: {a} vs {b}");
        }
    }

    #[test]
    fn replicated_b2_grad_identical_across_shards() {
        // the TP grad-sync invariant: every shard computes the same b2
        // gradient before any synchronisation
        let sp = spec(1);
        let (tokens, targets) = test_tokens(&sp, 3, 1);
        let tp = 4;
        let sp2 = sp.clone();
        let results = run_tp(tp, move |r, comm| {
            let st = BuiltinStage::sharded(sp2.clone(), 0, tp, r);
            let p = st.init(21);
            let (g, _) = st.bwd_single(&comm, &p, &tokens, &targets);
            let (lo, hi) = st.replicated_span();
            g[lo..hi].to_vec()
        });
        for r in 1..tp {
            for (a, b) in results[0].iter().zip(&results[r]) {
                assert!((a - b).abs() < 1e-6, "shard {r}: {a} vs {b}");
            }
        }
    }
}
