//! Pure-Rust reference stage backend — the XLA-free compute path.
//!
//! A deliberately small next-token model with the *same stage contract*
//! as the AOT-compiled GPT stages (embed on the first global stage, one
//! tanh-linear layer per stage, softmax-xent head on the last), so the
//! whole coordinator — schedules, virtual chunks, collectives, ZeRO-1 —
//! can be exercised end-to-end without PJRT artifacts.  The engine tests
//! use it to prove schedule equivalence (1F1B vs GPipe vs interleaved
//! must walk the same loss trajectory); gradients were validated against
//! finite differences when this module was written.
//!
//! Initialisation is keyed per *global* component (embedding, layer
//! index, head), never per stage, so any partition of the same model —
//! 1, 2, or `p·v` chunks — materialises bit-identical parameters.

use crate::data::Rng64;

/// Architecture + partition of one builtin bundle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuiltinSpec {
    pub name: String,
    pub vocab: usize,
    pub hidden: usize,
    pub seq: usize,
    pub mbs: usize,
    /// Global stages (= model layers; one tanh-linear layer per stage).
    pub n_stages: usize,
}

impl BuiltinSpec {
    /// Parse an engine bundle name of the form `builtin:<model>-s<K>-mb<B>`
    /// (e.g. `builtin:tiny-s4-mb2`).  Returns `None` for artifact bundles.
    pub fn parse(bundle: &str) -> Option<Self> {
        let rest = bundle.strip_prefix("builtin:")?;
        let (model, rest) = rest.split_once("-s")?;
        let (stages, mbs) = rest.split_once("-mb")?;
        let n_stages: usize = stages.parse().ok()?;
        let mbs: usize = mbs.parse().ok()?;
        if n_stages == 0 || mbs == 0 {
            return None;
        }
        let (vocab, hidden, seq) = match model {
            "tiny" => (64, 16, 8),
            "mini" => (128, 32, 16),
            _ => return None,
        };
        Some(Self { name: model.to_string(), vocab, hidden, seq, mbs, n_stages })
    }

    pub fn embed_params(&self) -> usize {
        self.vocab * self.hidden
    }

    pub fn layer_params(&self) -> usize {
        self.hidden * self.hidden + self.hidden
    }

    pub fn head_params(&self) -> usize {
        self.hidden * self.vocab + self.vocab
    }

    pub fn total_params(&self) -> usize {
        self.embed_params() + self.n_stages * self.layer_params() + self.head_params()
    }

    /// Parameters held by global stage `g` (embed on 0, head on last).
    pub fn stage_params(&self, g: usize) -> usize {
        let mut n = self.layer_params();
        if g == 0 {
            n += self.embed_params();
        }
        if g == self.n_stages - 1 {
            n += self.head_params();
        }
        n
    }
}

/// One global stage of the builtin model: optional embed, one tanh-linear
/// layer, optional softmax-xent head.
#[derive(Debug, Clone)]
pub struct BuiltinStage {
    pub spec: BuiltinSpec,
    /// Global stage index (= global layer index).
    pub stage: usize,
}

/// Per-component init streams keyed by (run seed, global component id) so
/// every partition of the model draws identical values.
fn component_rng(seed: u64, salt: u64) -> Rng64 {
    Rng64::new(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ salt ^ 0x5EED_CAFE)
}

impl BuiltinStage {
    fn d(&self) -> usize {
        self.spec.hidden
    }

    fn v(&self) -> usize {
        self.spec.vocab
    }

    pub fn has_embed(&self) -> bool {
        self.stage == 0
    }

    pub fn has_head(&self) -> bool {
        self.stage == self.spec.n_stages - 1
    }

    pub fn param_count(&self) -> usize {
        self.spec.stage_params(self.stage)
    }

    /// Offsets of (embed, layer W, layer b, head W, head b) in the flat
    /// parameter vector.
    fn layout(&self) -> (usize, usize, usize, usize) {
        let embed = if self.has_embed() { self.spec.embed_params() } else { 0 };
        let d = self.d();
        let w = embed;
        let b = w + d * d;
        let hw = b + d;
        let hb = hw + if self.has_head() { d * self.v() } else { 0 };
        (w, b, hw, hb)
    }

    /// Deterministic, partition-invariant init of this stage's flat
    /// parameter vector.
    pub fn init(&self, seed: u64) -> Vec<f32> {
        let d = self.d();
        let mut out = Vec::with_capacity(self.param_count());
        if self.has_embed() {
            let mut rng = component_rng(seed, 0xE0_BED);
            out.extend((0..self.spec.embed_params()).map(|_| (rng.normal() * 0.5) as f32));
        }
        let mut rng = component_rng(seed, 0x1A7E5 + self.stage as u64);
        let scale = 1.0 / (d as f64).sqrt();
        out.extend((0..d * d).map(|_| (rng.normal() * scale) as f32));
        out.extend(std::iter::repeat(0.0f32).take(d)); // layer bias
        if self.has_head() {
            let mut rng = component_rng(seed, 0xD_EAD);
            out.extend((0..d * self.v()).map(|_| (rng.normal() * scale) as f32));
            out.extend(std::iter::repeat(0.0f32).take(self.v())); // head bias
        }
        debug_assert_eq!(out.len(), self.param_count());
        out
    }

    /// Embed a token block into the layer input `x` (t-major, d-minor).
    fn embed(&self, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        let d = self.d();
        let mut x = Vec::with_capacity(tokens.len() * d);
        for &t in tokens {
            let row = t as usize * d;
            x.extend_from_slice(&params[row..row + d]);
        }
        x
    }

    /// One tanh-linear layer forward: `h = tanh(x W + b)`.
    fn layer_fwd(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        let d = self.d();
        let (w0, b0, _, _) = self.layout();
        let (w, b) = (&params[w0..w0 + d * d], &params[b0..b0 + d]);
        let t_count = x.len() / d;
        let mut h = vec![0.0f32; x.len()];
        for t in 0..t_count {
            let xi = &x[t * d..(t + 1) * d];
            let ho = &mut h[t * d..(t + 1) * d];
            ho.copy_from_slice(b);
            for (i, &xv) in xi.iter().enumerate() {
                let wrow = &w[i * d..(i + 1) * d];
                for (o, &wv) in ho.iter_mut().zip(wrow) {
                    *o += xv * wv;
                }
            }
            for o in ho.iter_mut() {
                *o = o.tanh();
            }
        }
        h
    }

    /// Layer backward given the stage input `x` and upstream grad `dh`
    /// (recomputes the forward — checkpointing semantics).  Writes dW/db
    /// into `gparams` and returns `dx`.
    fn layer_bwd(&self, params: &[f32], gparams: &mut [f32], x: &[f32], dh: &[f32]) -> Vec<f32> {
        let d = self.d();
        let (w0, b0, _, _) = self.layout();
        let h = self.layer_fwd(params, x);
        let w = &params[w0..w0 + d * d];
        let t_count = x.len() / d;
        let mut dx = vec![0.0f32; x.len()];
        for t in 0..t_count {
            let xi = &x[t * d..(t + 1) * d];
            let hi = &h[t * d..(t + 1) * d];
            let dhi = &dh[t * d..(t + 1) * d];
            // dpre = dh * (1 - h^2)
            let dpre: Vec<f32> = dhi
                .iter()
                .zip(hi)
                .map(|(&g, &hv)| g * (1.0 - hv * hv))
                .collect();
            for (j, &dp) in dpre.iter().enumerate() {
                gparams[b0 + j] += dp;
            }
            let dxi = &mut dx[t * d..(t + 1) * d];
            for (i, &xv) in xi.iter().enumerate() {
                let grow = &mut gparams[w0 + i * d..w0 + (i + 1) * d];
                let wrow = &w[i * d..(i + 1) * d];
                let mut acc = 0.0f32;
                for ((gw, &dp), &wv) in grow.iter_mut().zip(&dpre).zip(wrow) {
                    *gw += xv * dp;
                    acc += dp * wv;
                }
                dxi[i] = acc;
            }
        }
        dx
    }

    /// Head loss + backward: returns (dh into the layer output, mean loss).
    fn head_bwd(
        &self,
        params: &[f32],
        gparams: &mut [f32],
        h: &[f32],
        targets: &[i32],
    ) -> (Vec<f32>, f32) {
        let d = self.d();
        let v = self.v();
        let (_, _, hw0, hb0) = self.layout();
        let wh = &params[hw0..hw0 + d * v];
        let t_count = h.len() / d;
        let inv_t = 1.0 / t_count as f32;
        let mut dh = vec![0.0f32; h.len()];
        let mut loss = 0.0f32;
        let mut logits = vec![0.0f32; v];
        for t in 0..t_count {
            let hi = &h[t * d..(t + 1) * d];
            logits.copy_from_slice(&params[hb0..hb0 + v]);
            for (i, &hv) in hi.iter().enumerate() {
                let wrow = &wh[i * v..(i + 1) * v];
                for (l, &wv) in logits.iter_mut().zip(wrow) {
                    *l += hv * wv;
                }
            }
            // stable softmax-xent
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - max).exp();
                z += *l;
            }
            let tgt = targets[t] as usize;
            loss -= (logits[tgt] / z).max(1e-30).ln() * inv_t;
            // dlogits = (softmax - onehot) / T, reusing `logits` as probs
            for (u, l) in logits.iter_mut().enumerate() {
                *l = (*l / z - f32::from(u == tgt)) * inv_t;
            }
            for (u, &dl) in logits.iter().enumerate() {
                gparams[hb0 + u] += dl;
            }
            let dhi = &mut dh[t * d..(t + 1) * d];
            for (i, &hv) in hi.iter().enumerate() {
                let grow = &mut gparams[hw0 + i * v..hw0 + (i + 1) * v];
                let wrow = &wh[i * v..(i + 1) * v];
                let mut acc = 0.0f32;
                for ((gw, &dl), &wv) in grow.iter_mut().zip(logits.iter()).zip(wrow) {
                    *gw += hv * dl;
                    acc += dl * wv;
                }
                dhi[i] = acc;
            }
        }
        (dh, loss)
    }

    /// Embedding backward: scatter `dx` rows into the table gradient.
    fn embed_bwd(&self, gparams: &mut [f32], tokens: &[i32], dx: &[f32]) {
        let d = self.d();
        for (t, &tok) in tokens.iter().enumerate() {
            let row = tok as usize * d;
            for (g, &v) in gparams[row..row + d].iter_mut().zip(&dx[t * d..(t + 1) * d]) {
                *g += v;
            }
        }
    }

    // ---- the five stage entry points the worker drives ----

    /// First-stage forward: tokens -> activation.
    pub fn fwd_first(&self, params: &[f32], tokens: &[i32]) -> Vec<f32> {
        let x = self.embed(params, tokens);
        self.layer_fwd(params, &x)
    }

    /// Middle-stage forward: activation -> activation.
    pub fn fwd_mid(&self, params: &[f32], x: &[f32]) -> Vec<f32> {
        self.layer_fwd(params, x)
    }

    /// Last-stage backward: (stage input, targets) -> (gparams, gx, loss).
    pub fn bwd_last(&self, params: &[f32], x: &[f32], targets: &[i32]) -> (Vec<f32>, Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let h = self.layer_fwd(params, x);
        let (dh, loss) = self.head_bwd(params, &mut g, &h, targets);
        let dx = self.layer_bwd(params, &mut g, x, &dh);
        (g, dx, loss)
    }

    /// Middle-stage backward: (stage input, upstream grad) -> (gparams, gx).
    pub fn bwd_mid(&self, params: &[f32], x: &[f32], gy: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut g = vec![0.0f32; params.len()];
        let dx = self.layer_bwd(params, &mut g, x, gy);
        (g, dx)
    }

    /// First-stage backward: (tokens, upstream grad) -> gparams.
    pub fn bwd_first(&self, params: &[f32], tokens: &[i32], gy: &[f32]) -> Vec<f32> {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(params, tokens);
        let dx = self.layer_bwd(params, &mut g, &x, gy);
        self.embed_bwd(&mut g, tokens, &dx);
        g
    }

    /// Fused single-stage backward (K = 1): (tokens, targets) ->
    /// (gparams, loss).
    pub fn bwd_single(&self, params: &[f32], tokens: &[i32], targets: &[i32]) -> (Vec<f32>, f32) {
        let mut g = vec![0.0f32; params.len()];
        let x = self.embed(params, tokens);
        let h = self.layer_fwd(params, &x);
        let (dh, loss) = self.head_bwd(params, &mut g, &h, targets);
        let dx = self.layer_bwd(params, &mut g, &x, &dh);
        self.embed_bwd(&mut g, tokens, &dx);
        (g, loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(k: usize) -> BuiltinSpec {
        BuiltinSpec::parse(&format!("builtin:tiny-s{k}-mb2")).unwrap()
    }

    fn stage(sp: &BuiltinSpec, g: usize) -> BuiltinStage {
        BuiltinStage { spec: sp.clone(), stage: g }
    }

    #[test]
    fn parse_bundle_names() {
        let sp = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
        assert_eq!((sp.n_stages, sp.mbs, sp.hidden), (4, 2, 16));
        assert!(BuiltinSpec::parse("tiny-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:nope-s4-mb2").is_none());
        assert!(BuiltinSpec::parse("builtin:tiny-s0-mb2").is_none());
    }

    #[test]
    fn stage_params_sum_to_total() {
        for k in [1usize, 2, 4] {
            let sp = spec(k);
            let sum: usize = (0..k).map(|g| sp.stage_params(g)).sum();
            assert_eq!(sum, sp.total_params());
            for g in 0..k {
                assert_eq!(stage(&sp, g).init(7).len(), sp.stage_params(g));
            }
        }
    }

    #[test]
    fn init_is_partition_invariant() {
        // layer 1's weights must be identical whether the model is cut
        // into 2 or 4 stages (global component keys)
        let s2 = stage(&spec(2), 1);
        let s4 = stage(&spec(4), 1);
        let p2 = s2.init(42);
        let p4 = s4.init(42);
        let d = 16;
        // s2 stage 1: [W, b, head]; s4 stage 1: [W, b] — same leading W
        assert_eq!(&p2[..d * d], &p4[..d * d]);
    }

    #[test]
    fn gradcheck_single_stage() {
        // finite differences on the fused path (the multi-stage paths are
        // compositions of the same layer/head/embed pieces)
        let sp = spec(1);
        let st = stage(&sp, 0);
        let mut params = st.init(3);
        let t = sp.mbs * sp.seq;
        let tokens: Vec<i32> = (0..t).map(|i| (i * 7 % sp.vocab) as i32).collect();
        let targets: Vec<i32> = (0..t).map(|i| ((i * 7 + 1) % sp.vocab) as i32).collect();
        let (g, _) = st.bwd_single(&params, &tokens, &targets);
        let eps = 1e-3f32;
        let mut worst = 0.0f32;
        for idx in [0usize, 100, 1024, 1024 + 50, 1024 + 272 + 10, params.len() - 1] {
            let orig = params[idx];
            params[idx] = orig + eps;
            let (_, lp) = st.bwd_single(&params, &tokens, &targets);
            params[idx] = orig - eps;
            let (_, lm) = st.bwd_single(&params, &tokens, &targets);
            params[idx] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            worst = worst.max((fd - g[idx]).abs());
        }
        assert!(worst < 2e-3, "finite-diff mismatch: {worst}");
    }

    #[test]
    fn pipeline_composition_matches_fused() {
        // chaining stage entry points across a 2-stage cut must produce
        // the same loss and the same embedding gradient as... two stacked
        // layers differ from one, so instead check: fwd_first -> bwd_last
        // over a 2-stage model reproduces bwd_single of the SAME 2-layer
        // model composed manually
        let sp = spec(2);
        let s0 = stage(&sp, 0);
        let s1 = stage(&sp, 1);
        let p0 = s0.init(9);
        let p1 = s1.init(9);
        let t = sp.mbs * sp.seq;
        let tokens: Vec<i32> = (0..t).map(|i| (i * 5 % sp.vocab) as i32).collect();
        let targets: Vec<i32> = (0..t).map(|i| ((i * 5 + 1) % sp.vocab) as i32).collect();

        let y0 = s0.fwd_first(&p0, &tokens);
        let (g1, gx, loss) = s1.bwd_last(&p1, &y0, &targets);
        let g0 = s0.bwd_first(&p0, &tokens, &gx);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(g0.iter().any(|&x| x != 0.0));
        assert!(g1.iter().any(|&x| x != 0.0));

        // numeric spot-check of the cross-stage chain: finite-diff through
        // the composed forward wrt one weight of stage 0's layer
        let fwd_loss = |p0: &[f32]| -> f32 {
            let y0 = s0.fwd_first(p0, &tokens);
            let (_, _, l) = s1.bwd_last(&p1, &y0, &targets);
            l
        };
        let idx = sp.embed_params() + 3; // a layer-W element
        let eps = 1e-3f32;
        let mut pp = p0.clone();
        pp[idx] += eps;
        let lp = fwd_loss(&pp);
        pp[idx] = p0[idx] - eps;
        let lm = fwd_loss(&pp);
        let fd = (lp - lm) / (2.0 * eps);
        assert!((fd - g0[idx]).abs() < 2e-3, "fd {fd} vs analytic {}", g0[idx]);
    }
}
