//! Cache-blocked matmul kernels for the builtin stage backend.
//!
//! The builtin stages originally walked every GEMM one token at a time
//! (a vector–matrix product per row), which re-streams the full weight
//! panel from memory for every token and leaves the backward's
//! transposed products as scalar dot-product chains LLVM cannot
//! vectorise (float addition is not associative).  These kernels fix
//! both on the training step's critical path:
//!
//! * **Register tiling** — [`MR`] output rows are produced per inner
//!   sweep, so each weight row loaded from cache is reused `MR` times
//!   and the inner loop carries `MR` independent, unit-stride FMA
//!   streams the auto-vectoriser can turn into vector code.
//! * **Transposed weight layout for the backward** — `dx = dy · Wᵀ` is
//!   computed by materialising `Wᵀ` once per call ([`matmul_bt_acc`])
//!   and reusing the forward kernel, trading an `O(k·n)` transpose
//!   (amortised over the `t` output rows) for a unit-stride inner loop
//!   in place of strided dot products.
//!
//! **Numerics contract:** every kernel accumulates each output element
//! in exactly the same order as the naive one-row-at-a-time loops it
//! replaces (`k` ascending for [`matmul_acc`], tokens ascending as
//! separate adds for [`matmul_at_acc`] / [`col_sum_acc`]), so blocked
//! and naive results are **bit-identical** — for [`matmul_bt_acc`]
//! given the zeroed output buffer its callers always pass (the naive
//! loop folds each dot product through a local accumulator before
//! adding it, which only coincides when the output starts at 0.0) —
//! and the engine's trajectory and determinism tests hold unchanged.
//! The `naive` module keeps the original loops as the oracle for the
//! equality tests below and as the pre-optimisation baseline the
//! `engine_hotpath` bench records in `BENCH_engine.json`.

/// Output rows per register tile (weight-row reuse factor).
pub const MR: usize = 4;

/// `out[t×n] += a[t×k] · b[k×n]` (all row-major, `b` in the natural
/// "input-dim × output-dim" layout with unit-stride output rows).
pub fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), t * n);
    debug_assert_eq!(a.len(), t * k);
    debug_assert_eq!(b.len(), k * n);
    if t == 0 || k == 0 || n == 0 {
        return;
    }
    let mut ti = 0;
    while ti + MR <= t {
        let (r0, rest) = out[ti * n..(ti + MR) * n].split_at_mut(n);
        let (r1, rest) = rest.split_at_mut(n);
        let (r2, r3) = rest.split_at_mut(n);
        let a0 = &a[ti * k..(ti + 1) * k];
        let a1 = &a[(ti + 1) * k..(ti + 2) * k];
        let a2 = &a[(ti + 2) * k..(ti + 3) * k];
        let a3 = &a[(ti + 3) * k..(ti + 4) * k];
        for kk in 0..k {
            let (x0, x1, x2, x3) = (a0[kk], a1[kk], a2[kk], a3[kk]);
            let brow = &b[kk * n..(kk + 1) * n];
            for ((((o0, o1), o2), o3), &w) in r0
                .iter_mut()
                .zip(r1.iter_mut())
                .zip(r2.iter_mut())
                .zip(r3.iter_mut())
                .zip(brow)
            {
                *o0 += x0 * w;
                *o1 += x1 * w;
                *o2 += x2 * w;
                *o3 += x3 * w;
            }
        }
        ti += MR;
    }
    while ti < t {
        let row = &mut out[ti * n..(ti + 1) * n];
        let arow = &a[ti * k..(ti + 1) * k];
        for (kk, &x) in arow.iter().enumerate() {
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &w) in row.iter_mut().zip(brow) {
                *o += x * w;
            }
        }
        ti += 1;
    }
}

/// Weight-gradient accumulation `w[k×n] += aᵀ · g` for `a[t×k]`,
/// `g[t×n]`: rank-1 updates blocked [`MR`] tokens at a time, so each
/// weight row is read and written once per `MR` tokens instead of once
/// per token.  Per-element adds stay in token order (separate
/// statements — the compiler cannot reassociate them), keeping the
/// result bit-identical to the one-token-at-a-time loop.
pub fn matmul_at_acc(w: &mut [f32], a: &[f32], g: &[f32], t: usize, k: usize, n: usize) {
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(a.len(), t * k);
    debug_assert_eq!(g.len(), t * n);
    if t == 0 || k == 0 || n == 0 {
        return;
    }
    let mut ti = 0;
    while ti + MR <= t {
        let g0 = &g[ti * n..(ti + 1) * n];
        let g1 = &g[(ti + 1) * n..(ti + 2) * n];
        let g2 = &g[(ti + 2) * n..(ti + 3) * n];
        let g3 = &g[(ti + 3) * n..(ti + 4) * n];
        let a0 = &a[ti * k..(ti + 1) * k];
        let a1 = &a[(ti + 1) * k..(ti + 2) * k];
        let a2 = &a[(ti + 2) * k..(ti + 3) * k];
        let a3 = &a[(ti + 3) * k..(ti + 4) * k];
        for i in 0..k {
            let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
            let wrow = &mut w[i * n..(i + 1) * n];
            for ((((wv, &v0), &v1), &v2), &v3) in
                wrow.iter_mut().zip(g0).zip(g1).zip(g2).zip(g3)
            {
                *wv += x0 * v0;
                *wv += x1 * v1;
                *wv += x2 * v2;
                *wv += x3 * v3;
            }
        }
        ti += MR;
    }
    while ti < t {
        let grow = &g[ti * n..(ti + 1) * n];
        let arow = &a[ti * k..(ti + 1) * k];
        for (i, &x) in arow.iter().enumerate() {
            let wrow = &mut w[i * n..(i + 1) * n];
            for (wv, &v) in wrow.iter_mut().zip(grow) {
                *wv += x * v;
            }
        }
        ti += 1;
    }
}

/// Transposed-weight product `out[t×k] += g[t×n] · bᵀ` for `b[k×n]`
/// (i.e. `out[t][i] += Σ_j g[t][j] · b[i][j]` — the backward data
/// gradients `dx = dpre · W1ᵀ`, `dh = dy · W2ᵀ`).  Materialises `bᵀ`
/// once and defers to [`matmul_acc`]; summation stays `j`-ascending,
/// bit-identical to the scalar dot-product loop it replaces **when
/// `out` starts zeroed** (as every builtin call site does — the naive
/// loop sums into a local accumulator before adding it once).
pub fn matmul_bt_acc(out: &mut [f32], g: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), t * k);
    debug_assert_eq!(g.len(), t * n);
    debug_assert_eq!(b.len(), k * n);
    if t == 0 || k == 0 || n == 0 {
        return;
    }
    let mut bt = vec![0.0f32; n * k];
    for i in 0..k {
        for (j, &v) in b[i * n..(i + 1) * n].iter().enumerate() {
            bt[j * k + i] = v;
        }
    }
    matmul_acc(out, g, &bt, t, n, k);
}

/// Column sums `acc[n] += Σ_t g[t][n]` (bias gradients), token order.
pub fn col_sum_acc(acc: &mut [f32], g: &[f32], t: usize, n: usize) {
    debug_assert_eq!(acc.len(), n);
    debug_assert_eq!(g.len(), t * n);
    for ti in 0..t {
        for (av, &v) in acc.iter_mut().zip(&g[ti * n..(ti + 1) * n]) {
            *av += v;
        }
    }
}

/// bf16-in / f32-accumulate GEMM paths (the MI250X matrix-core contract
/// the paper's mixed-precision throughput assumes): inputs are
/// constrained to the bf16 grid, every product and accumulation runs in
/// f32.  Because a product of two bf16 values (8-bit significands) is
/// exact in f32, "quantize the operands, then run the blocked f32
/// kernel" IS the bf16 GEMM, bit for bit — same register tiling, same
/// accumulation order as the fp32 path, so the fp32/bf16 pair differ
/// only by the input cast.  Idempotent over already-quantized storage
/// (the builtin stages' buffers), by [`crate::precision::Dtype`]'s
/// quantize idempotence.
pub mod bf16 {
    use crate::precision::Dtype;

    /// `out[t×n] += bf16(a)[t×k] · bf16(b)[k×n]`, f32 accumulation.
    pub fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
        let (aq, bq) = (Dtype::Bf16.quantized(a), Dtype::Bf16.quantized(b));
        super::matmul_acc(out, &aq, &bq, t, k, n);
    }

    /// `w[k×n] += bf16(a)ᵀ · bf16(g)`, f32 accumulation.
    pub fn matmul_at_acc(w: &mut [f32], a: &[f32], g: &[f32], t: usize, k: usize, n: usize) {
        let (aq, gq) = (Dtype::Bf16.quantized(a), Dtype::Bf16.quantized(g));
        super::matmul_at_acc(w, &aq, &gq, t, k, n);
    }

    /// `out[t×k] += bf16(g) · bf16(b)ᵀ`, f32 accumulation.
    pub fn matmul_bt_acc(out: &mut [f32], g: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
        let (gq, bq) = (Dtype::Bf16.quantized(g), Dtype::Bf16.quantized(b));
        super::matmul_bt_acc(out, &gq, &bq, t, k, n);
    }
}

/// The original one-row-at-a-time loops: the correctness oracle for the
/// equality tests and the pre-optimisation baseline `engine_hotpath`
/// times against the blocked kernels.
pub mod naive {
    /// `out[t×n] += a[t×k] · b[k×n]`, one token per sweep.
    pub fn matmul_acc(out: &mut [f32], a: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
        for ti in 0..t {
            let row = &mut out[ti * n..(ti + 1) * n];
            for (kk, &x) in a[ti * k..(ti + 1) * k].iter().enumerate() {
                let brow = &b[kk * n..(kk + 1) * n];
                for (o, &w) in row.iter_mut().zip(brow) {
                    *o += x * w;
                }
            }
        }
    }

    /// `w[k×n] += aᵀ · g`, one rank-1 update per token.
    pub fn matmul_at_acc(w: &mut [f32], a: &[f32], g: &[f32], t: usize, k: usize, n: usize) {
        for ti in 0..t {
            let grow = &g[ti * n..(ti + 1) * n];
            for (i, &x) in a[ti * k..(ti + 1) * k].iter().enumerate() {
                let wrow = &mut w[i * n..(i + 1) * n];
                for (wv, &v) in wrow.iter_mut().zip(grow) {
                    *wv += x * v;
                }
            }
        }
    }

    /// `out[t×k] += g · bᵀ`, scalar dot products along weight rows.
    pub fn matmul_bt_acc(out: &mut [f32], g: &[f32], b: &[f32], t: usize, k: usize, n: usize) {
        for ti in 0..t {
            let grow = &g[ti * n..(ti + 1) * n];
            let orow = &mut out[ti * k..(ti + 1) * k];
            for (i, o) in orow.iter_mut().enumerate() {
                let brow = &b[i * n..(i + 1) * n];
                let mut acc = 0.0f32;
                for (&gv, &wv) in grow.iter().zip(brow) {
                    acc += gv * wv;
                }
                *o += acc;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fill(seed: usize, len: usize) -> Vec<f32> {
        (0..len).map(|i| ((seed * 31 + i) as f32 * 0.17).sin()).collect()
    }

    /// Shapes covering the register-tile remainders (t % MR ∈ {0..3}),
    /// degenerate dims, and larger-than-tile sizes.
    fn shapes() -> Vec<(usize, usize, usize)> {
        vec![
            (1, 1, 1),
            (2, 3, 5),
            (3, 8, 8),
            (4, 16, 16),
            (5, 7, 9),
            (7, 16, 4),
            (8, 4, 16),
            (16, 16, 16),
            (9, 33, 17),
        ]
    }

    #[test]
    fn blocked_matmul_matches_naive_bitwise() {
        for (t, k, n) in shapes() {
            let a = fill(1, t * k);
            let b = fill(2, k * n);
            let mut blocked = fill(3, t * n);
            let mut reference = blocked.clone();
            matmul_acc(&mut blocked, &a, &b, t, k, n);
            naive::matmul_acc(&mut reference, &a, &b, t, k, n);
            assert_eq!(blocked, reference, "matmul t={t} k={k} n={n}");
        }
    }

    #[test]
    fn blocked_at_matches_naive_bitwise() {
        for (t, k, n) in shapes() {
            let a = fill(4, t * k);
            let g = fill(5, t * n);
            let mut blocked = fill(6, k * n);
            let mut reference = blocked.clone();
            matmul_at_acc(&mut blocked, &a, &g, t, k, n);
            naive::matmul_at_acc(&mut reference, &a, &g, t, k, n);
            assert_eq!(blocked, reference, "at t={t} k={k} n={n}");
        }
    }

    #[test]
    fn blocked_bt_matches_naive_bitwise() {
        // zeroed outputs, as every call site passes: the naive loop
        // folds each dot product through a local accumulator, so the
        // bit-identity only holds from a 0.0 starting value
        for (t, k, n) in shapes() {
            let g = fill(7, t * n);
            let b = fill(8, k * n);
            let mut blocked = vec![0.0f32; t * k];
            let mut reference = vec![0.0f32; t * k];
            matmul_bt_acc(&mut blocked, &g, &b, t, k, n);
            naive::matmul_bt_acc(&mut reference, &g, &b, t, k, n);
            assert_eq!(blocked, reference, "bt t={t} k={k} n={n}");
        }
    }

    #[test]
    fn matmul_known_values() {
        // [1 2; 3 4] · [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0, 2.0, 3.0, 4.0];
        let b = [5.0, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul_acc(&mut out, &a, &b, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
        // bt against the transpose: a · bᵀ where bᵀ = [5 7; 6 8]
        let mut out = [0.0f32; 4];
        matmul_bt_acc(&mut out, &a, &b, 2, 2, 2);
        assert_eq!(out, [17.0, 23.0, 39.0, 53.0]);
        // aᵀ · b = [1 3; 2 4] · [5 6; 7 8] = [26 30; 38 44]
        let mut out = [0.0f32; 4];
        matmul_at_acc(&mut out, &a, &b, 2, 2, 2);
        assert_eq!(out, [26.0, 30.0, 38.0, 44.0]);
    }

    #[test]
    fn col_sum_known_values() {
        let g = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut acc = [0.5f32, 0.5];
        col_sum_acc(&mut acc, &g, 3, 2);
        assert_eq!(acc, [9.5, 12.5]);
    }

    #[test]
    fn accumulates_instead_of_overwriting() {
        let a = [1.0f32];
        let b = [2.0f32];
        let mut out = [10.0f32];
        matmul_acc(&mut out, &a, &b, 1, 1, 1);
        assert_eq!(out, [12.0]);
    }

    #[test]
    fn bf16_kernels_equal_f32_kernels_over_quantized_inputs() {
        use crate::precision::Dtype;
        for (t, k, n) in shapes() {
            let a = fill(11, t * k);
            let b = fill(12, k * n);
            let g = fill(13, t * n);
            let (aq, bq, gq) =
                (Dtype::Bf16.quantized(&a), Dtype::Bf16.quantized(&b), Dtype::Bf16.quantized(&g));

            let mut got = vec![0.0f32; t * n];
            let mut want = vec![0.0f32; t * n];
            bf16::matmul_acc(&mut got, &a, &b, t, k, n);
            matmul_acc(&mut want, &aq, &bq, t, k, n);
            assert_eq!(got, want, "mm t={t} k={k} n={n}");

            let mut got = vec![0.0f32; k * n];
            let mut want = vec![0.0f32; k * n];
            bf16::matmul_at_acc(&mut got, &a, &g, t, k, n);
            matmul_at_acc(&mut want, &aq, &gq, t, k, n);
            assert_eq!(got, want, "at t={t} k={k} n={n}");

            let mut got = vec![0.0f32; t * k];
            let mut want = vec![0.0f32; t * k];
            bf16::matmul_bt_acc(&mut got, &g, &b, t, k, n);
            matmul_bt_acc(&mut want, &gq, &bq, t, k, n);
            assert_eq!(got, want, "bt t={t} k={k} n={n}");

            // idempotent over pre-quantized storage: re-running the bf16
            // kernel on quantized inputs changes nothing
            let mut again = vec![0.0f32; t * k];
            bf16::matmul_bt_acc(&mut again, &gq, &bq, t, k, n);
            assert_eq!(again, got, "idempotence t={t} k={k} n={n}");
        }
    }
}
