//! Stage-compute runtime: AOT HLO artifacts on PJRT, or the pure-Rust
//! builtin reference backend.
//!
//! The coordinator drives every pipeline stage through one typed contract
//! ([`StageExecutables`]): init / forward / backward entry points over
//! flat `f32` parameter vectors and `(b, s, d)` activations.  Two
//! backends implement it:
//!
//! * **Xla** — the AOT HLO-text artifacts emitted by
//!   `python/compile/aot.py`, compiled once on the PJRT CPU client
//!   (`PjRtClient::cpu` -> `HloModuleProto::from_text_file` ->
//!   `client.compile` -> `execute`).  HLO *text* is the interchange
//!   format — jax >= 0.5 serialises protos with 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!   Python is never on the training path.
//! * **Builtin** — `runtime::builtin`, a small tanh-linear next-token
//!   model with hand-written gradients.  No artifacts, no PJRT: it keeps
//!   the full distributed engine executable (and testable in CI) on
//!   machines without the XLA toolchain.  Bundle names of the form
//!   `builtin:tiny-s4-mb2` select it.

pub mod builtin;
pub mod kernels;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::collectives::TpComm;
use crate::moe::MoeFwdCtx;
use crate::util::json::Json;

pub use builtin::{BuiltinSpec, BuiltinStage};

/// meta.json emitted by `python/compile/aot.py` for one artifact bundle.
#[derive(Debug, Clone)]
pub struct BundleMeta {
    pub model: ModelMeta,
    pub n_stages: u32,
    pub mbs: u32,
    pub use_flash: bool,
    pub use_fused_xent: bool,
    pub tokens_per_microbatch: u64,
    pub flops_per_microbatch: f64,
    pub stages: Vec<StageMeta>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: u32,
    pub hidden: u64,
    pub n_heads: u32,
    pub vocab: u64,
    pub seq: u64,
    pub total_params: u64,
}

#[derive(Debug, Clone)]
pub struct StageMeta {
    pub index: u32,
    pub layer_start: u32,
    pub layer_end: u32,
    pub has_embed: bool,
    pub has_head: bool,
    pub param_count: u64,
    pub artifacts: StageArtifacts,
}

#[derive(Debug, Clone)]
pub struct StageArtifacts {
    pub init: String,
    pub fwd: String,
    pub bwd: String,
}

impl BundleMeta {
    /// Parse the aot.py meta.json (in-tree JSON parser; offline build).
    pub fn from_json(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let m = j.field("model").map_err(|e| anyhow!("{e}"))?;
        let model = ModelMeta {
            name: m.str_field("name").map_err(|e| anyhow!("{e}"))?,
            n_layers: m.u64_field("n_layers").map_err(|e| anyhow!("{e}"))? as u32,
            hidden: m.u64_field("hidden").map_err(|e| anyhow!("{e}"))?,
            n_heads: m.u64_field("n_heads").map_err(|e| anyhow!("{e}"))? as u32,
            vocab: m.u64_field("vocab").map_err(|e| anyhow!("{e}"))?,
            seq: m.u64_field("seq").map_err(|e| anyhow!("{e}"))?,
            total_params: m.u64_field("total_params").map_err(|e| anyhow!("{e}"))?,
        };
        let stages_json = j
            .field("stages")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("stages must be an array"))?;
        let mut stages = Vec::with_capacity(stages_json.len());
        for s in stages_json {
            let a = s.field("artifacts").map_err(|e| anyhow!("{e}"))?;
            stages.push(StageMeta {
                index: s.u64_field("index").map_err(|e| anyhow!("{e}"))? as u32,
                layer_start: s.u64_field("layer_start").map_err(|e| anyhow!("{e}"))? as u32,
                layer_end: s.u64_field("layer_end").map_err(|e| anyhow!("{e}"))? as u32,
                has_embed: s.bool_field("has_embed").map_err(|e| anyhow!("{e}"))?,
                has_head: s.bool_field("has_head").map_err(|e| anyhow!("{e}"))?,
                param_count: s.u64_field("param_count").map_err(|e| anyhow!("{e}"))?,
                artifacts: StageArtifacts {
                    init: a.str_field("init").map_err(|e| anyhow!("{e}"))?,
                    fwd: a.str_field("fwd").map_err(|e| anyhow!("{e}"))?,
                    bwd: a.str_field("bwd").map_err(|e| anyhow!("{e}"))?,
                },
            });
        }
        Ok(BundleMeta {
            model,
            n_stages: j.u64_field("n_stages").map_err(|e| anyhow!("{e}"))? as u32,
            mbs: j.u64_field("mbs").map_err(|e| anyhow!("{e}"))? as u32,
            use_flash: j.bool_field("use_flash").map_err(|e| anyhow!("{e}"))?,
            use_fused_xent: j.bool_field("use_fused_xent").map_err(|e| anyhow!("{e}"))?,
            tokens_per_microbatch: j
                .u64_field("tokens_per_microbatch")
                .map_err(|e| anyhow!("{e}"))?,
            flops_per_microbatch: j
                .f64_field("flops_per_microbatch")
                .map_err(|e| anyhow!("{e}"))?,
            stages,
        })
    }

    /// Synthesise the meta block for a builtin bundle (no files involved).
    pub fn for_builtin(spec: &BuiltinSpec) -> Self {
        let stages = (0..spec.n_stages)
            .map(|g| StageMeta {
                index: g as u32,
                layer_start: g as u32,
                layer_end: g as u32 + 1,
                has_embed: g == 0,
                has_head: g == spec.n_stages - 1,
                param_count: spec.stage_params(g) as u64,
                artifacts: StageArtifacts {
                    init: "builtin".into(),
                    fwd: "builtin".into(),
                    bwd: "builtin".into(),
                },
            })
            .collect();
        let total = spec.total_params() as u64;
        BundleMeta {
            model: ModelMeta {
                name: format!("builtin-{}", spec.name),
                n_layers: spec.n_stages as u32,
                hidden: spec.hidden as u64,
                n_heads: 1,
                vocab: spec.vocab as u64,
                seq: spec.seq as u64,
                total_params: total,
            },
            n_stages: spec.n_stages as u32,
            mbs: spec.mbs as u32,
            use_flash: false,
            use_fused_xent: true,
            tokens_per_microbatch: (spec.mbs * spec.seq) as u64,
            flops_per_microbatch: 6.0 * total as f64 * (spec.mbs * spec.seq) as f64,
            stages,
        }
    }
}

/// A compiled executable, shareable across worker threads.
///
/// SAFETY: the `xla` crate wraps raw pointers (hence `!Send`), but XLA's
/// `PjRtClient` and `PjRtLoadedExecutable` are documented thread-safe
/// (execution is internally synchronised per device).  We share only the
/// client and executables; `Literal`s and `PjRtBuffer`s stay thread-local.
///
/// NOTE on `execute` vs `execute_b`: the published xla crate's `execute`
/// entry point (xla_rs.cc) uploads every input literal to a fresh device
/// buffer and `release()`s it without ever freeing — every call leaks all
/// inputs.  We therefore ALWAYS go through `execute_b` with buffers this
/// wrapper owns (freed by `PjRtBuffer::drop`), which also lets the hot
/// path upload the big parameter buffer once per step instead of once per
/// micro-batch.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with device-buffer inputs (the hot path); flattens the
    /// 1-element replica dim and unpacks the output tuple (aot.py lowers
    /// with `return_tuple=True`).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let first = out
            .pop()
            .and_then(|mut d| if d.is_empty() { None } else { Some(d.swap_remove(0)) })
            .ok_or_else(|| anyhow!("executable produced no outputs"))?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with literal inputs: uploads to owned device buffers first
    /// (see the leak note above), then defers to [`Executable::run_b`].
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<_, _>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_b(&refs)
    }
}

/// The PJRT client plus helpers; one per process, shared by all workers.
/// `client` is `None` for builtin-only runtimes ([`Runtime::null`]), where
/// no device buffers ever exist.
pub struct Runtime {
    client: Option<xla::PjRtClient>,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Self { client: Some(client) }))
    }

    /// A runtime with no PJRT client — sufficient for builtin bundles,
    /// which never touch device buffers.
    pub fn null() -> Arc<Self> {
        Arc::new(Self { client: None })
    }

    pub fn platform(&self) -> String {
        match &self.client {
            Some(c) => c.platform_name(),
            None => "builtin".to_string(),
        }
    }

    fn client(&self) -> Result<&xla::PjRtClient> {
        self.client
            .as_ref()
            .ok_or_else(|| anyhow!("runtime has no PJRT client (builtin-only)"))
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let client = self.client()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe, client: client.clone() })
    }

    /// Upload an f32 host slice to an owned device buffer.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client()?.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Upload an i32 host slice to an owned device buffer.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client()?.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Upload a u32 host slice to an owned device buffer.
    pub fn buf_u32(&self, data: &[u32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client()?.buffer_from_host_buffer(data, dims, None)?))
    }
}

/// A device buffer owned by a single worker thread.  The `xla` wrapper
/// type is `!Send` only because of its raw pointer; PJRT CPU buffers are
/// safe to move between threads as long as use is externally synchronised,
/// which the engine guarantees (each buffer is created, used and dropped
/// by one worker).
pub struct OwnedBuffer(pub xla::PjRtBuffer);

unsafe impl Send for OwnedBuffer {}

/// Activation/token shapes of one bundle (what the buffer uploads need).
#[derive(Debug, Clone, Copy)]
pub struct StageDims {
    pub b: usize,
    pub s: usize,
    pub d: usize,
}

impl StageDims {
    pub fn act(&self) -> [usize; 3] {
        [self.b, self.s, self.d]
    }

    pub fn tok(&self) -> [usize; 2] {
        [self.b, self.s]
    }
}

/// Step-scoped parameter handle: uploaded once per step, reused by every
/// micro-batch of that stage (EXPERIMENTS.md §Perf).
pub enum ParamsHandle {
    /// Device buffer on the PJRT client.
    Xla(OwnedBuffer),
    /// Shared host buffer for the builtin backend — an `Arc` clone of
    /// the worker's parameter vector, so staging a step's params moves
    /// no bytes (the worker drops the handle before mutating the
    /// underlying buffer via `Arc::make_mut`).
    Host(Arc<Vec<f32>>),
}

impl ParamsHandle {
    fn xla(&self) -> Result<&xla::PjRtBuffer> {
        match self {
            ParamsHandle::Xla(b) => Ok(&b.0),
            ParamsHandle::Host(_) => Err(anyhow!("host params handed to XLA stage")),
        }
    }

    fn host(&self) -> Result<&[f32]> {
        match self {
            ParamsHandle::Host(p) => Ok(p.as_slice()),
            ParamsHandle::Xla(_) => Err(anyhow!("device params handed to builtin stage")),
        }
    }
}

/// Compute backend of one stage.
pub enum StageBackend {
    Xla { init: Executable, fwd: Executable, bwd: Executable },
    Builtin(BuiltinStage),
}

/// One pipeline stage's compiled entry points behind the typed contract
/// the workers drive.  `(chunk, mb)`-addressed virtual stages are just
/// multiple `StageExecutables` hosted by one worker; tensor-parallel
/// shards are `StageExecutables` derived via [`StageExecutables::tp_shard`]
/// whose entry points communicate through the [`TpComm`] handed to every
/// call (`TpComm::solo()` for the dense case — every collective no-ops).
pub struct StageExecutables {
    pub meta: StageMeta,
    pub backend: StageBackend,
}

impl StageExecutables {
    /// Derive the TP shard `(tp, tp_rank)` of this stage.  Only the
    /// builtin backend shards (the AOT HLO artifacts are compiled dense);
    /// requesting `tp > 1` on an XLA stage is an error.
    pub fn tp_shard(&self, tp: usize, tp_rank: usize) -> Result<StageExecutables> {
        anyhow::ensure!(tp >= 2 && tp_rank < tp, "bad shard coords {tp_rank}/{tp}");
        match &self.backend {
            StageBackend::Builtin(st) => {
                anyhow::ensure!(
                    st.spec.tp_ok(tp),
                    "tp {tp} does not divide hidden {} / vocab {}",
                    st.spec.hidden,
                    st.spec.vocab
                );
                let sharded = BuiltinStage::sharded(st.spec.clone(), st.stage, tp, tp_rank)
                    .with_policy(st.policy)
                    .with_capacity_factor(st.capacity_factor);
                let mut meta = self.meta.clone();
                meta.param_count = sharded.param_count() as u64;
                Ok(StageExecutables { meta, backend: StageBackend::Builtin(sharded) })
            }
            StageBackend::Xla { .. } => Err(anyhow!(
                "tensor parallelism (tp = {tp}) requires a builtin:* bundle — \
                 AOT artifact stages are compiled tensor-dense"
            )),
        }
    }

    /// Span of the TP-replicated parameters in this shard's flat vector
    /// (the engine mean-reduces their gradients across the TP group
    /// before the optimizer step).  `None` for dense stages.
    pub fn tp_replicated_span(&self) -> Option<(usize, usize)> {
        match &self.backend {
            StageBackend::Builtin(st) if st.tp > 1 => Some(st.replicated_span()),
            _ => None,
        }
    }
    /// Materialise this stage's flat parameter vector (deterministic in
    /// `seed`; identical across DP replicas and across pipeline
    /// partitions — init keys fold in GLOBAL layer indices on both
    /// backends).
    pub fn init_params(&self, seed: u64) -> Result<Vec<f32>> {
        match &self.backend {
            StageBackend::Xla { init, .. } => {
                let key = [seed as u32, 0x5eed_0000];
                let key_lit = lit_u32(&key, &[2])?;
                let out = init.run(&[&key_lit]).context("running stage init")?;
                let params = to_f32(&out[0])?;
                anyhow::ensure!(
                    params.len() as u64 == self.meta.param_count,
                    "init size mismatch: {} vs {}",
                    params.len(),
                    self.meta.param_count
                );
                Ok(params)
            }
            StageBackend::Builtin(st) => Ok(st.init(seed)),
        }
    }

    /// Upload (or stage) the parameter vector for this step's micro-batches.
    pub fn prepare_params(&self, rt: &Runtime, params: &[f32]) -> Result<ParamsHandle> {
        match &self.backend {
            StageBackend::Xla { .. } => {
                Ok(ParamsHandle::Xla(rt.buf_f32(params, &[params.len()])?))
            }
            StageBackend::Builtin(_) => Ok(ParamsHandle::Host(Arc::new(params.to_vec()))),
        }
    }

    /// Zero-copy variant of [`StageExecutables::prepare_params`] for
    /// callers that already hold the parameters behind an `Arc` (the
    /// engine's hot path): the builtin backend stages an `Arc` clone
    /// instead of copying the full vector every step.
    pub fn prepare_params_shared(
        &self,
        rt: &Runtime,
        params: &Arc<Vec<f32>>,
    ) -> Result<ParamsHandle> {
        match &self.backend {
            StageBackend::Xla { .. } => {
                Ok(ParamsHandle::Xla(rt.buf_f32(params, &[params.len()])?))
            }
            StageBackend::Builtin(_) => Ok(ParamsHandle::Host(params.clone())),
        }
    }

    /// The XLA backend runs tensor-dense: reject any sharded communicator.
    fn ensure_dense(comm: &TpComm, what: &str) -> Result<()> {
        anyhow::ensure!(
            comm.tp() == 1,
            "{what}: tensor parallelism requires the builtin backend"
        );
        Ok(())
    }

    /// The XLA backend has no MoE stages: reject expert-parallel wiring.
    fn ensure_local(ctx: &MoeFwdCtx, what: &str) -> Result<()> {
        anyhow::ensure!(
            ctx.a2a.is_none(),
            "{what}: expert parallelism requires the builtin backend"
        );
        Ok(())
    }

    /// First-stage forward: tokens -> activation.
    pub fn fwd_first(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        tokens: &[i32],
        dims: StageDims,
    ) -> Result<Vec<f32>> {
        self.fwd_first_ctx(rt, p, comm, tokens, dims, &MoeFwdCtx::LOCAL)
    }

    /// [`Self::fwd_first`] with MoE wiring (builtin backend only).
    pub fn fwd_first_ctx(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        tokens: &[i32],
        dims: StageDims,
        ctx: &MoeFwdCtx,
    ) -> Result<Vec<f32>> {
        match &self.backend {
            StageBackend::Xla { fwd, .. } => {
                Self::ensure_dense(comm, "fwd_first")?;
                Self::ensure_local(ctx, "fwd_first")?;
                let tok_buf = rt.buf_i32(tokens, &dims.tok())?;
                let out = fwd.run_b(&[p.xla()?, &tok_buf.0]).context("stage fwd (embed)")?;
                to_f32(&out[0])
            }
            StageBackend::Builtin(st) => Ok(st.fwd_first_ctx(comm, p.host()?, tokens, ctx)),
        }
    }

    /// Middle-stage forward: activation -> activation.
    pub fn fwd_mid(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        x: &[f32],
        dims: StageDims,
    ) -> Result<Vec<f32>> {
        self.fwd_mid_ctx(rt, p, comm, x, dims, &MoeFwdCtx::LOCAL)
    }

    /// [`Self::fwd_mid`] with MoE wiring (builtin backend only).
    pub fn fwd_mid_ctx(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        x: &[f32],
        dims: StageDims,
        ctx: &MoeFwdCtx,
    ) -> Result<Vec<f32>> {
        match &self.backend {
            StageBackend::Xla { fwd, .. } => {
                Self::ensure_dense(comm, "fwd_mid")?;
                Self::ensure_local(ctx, "fwd_mid")?;
                let x_buf = rt.buf_f32(x, &dims.act())?;
                let out = fwd.run_b(&[p.xla()?, &x_buf.0]).context("stage fwd")?;
                to_f32(&out[0])
            }
            StageBackend::Builtin(st) => Ok(st.fwd_mid_ctx(comm, p.host()?, x, ctx)),
        }
    }

    /// Fused single-stage backward: (tokens, targets) -> (grads, loss).
    pub fn bwd_single(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        tokens: &[i32],
        targets: &[i32],
        dims: StageDims,
    ) -> Result<(Vec<f32>, f32)> {
        self.bwd_single_ctx(rt, p, comm, tokens, targets, dims, &MoeFwdCtx::LOCAL)
    }

    /// [`Self::bwd_single`] with MoE wiring for the fused forward.
    pub fn bwd_single_ctx(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        tokens: &[i32],
        targets: &[i32],
        dims: StageDims,
        ctx: &MoeFwdCtx,
    ) -> Result<(Vec<f32>, f32)> {
        match &self.backend {
            StageBackend::Xla { bwd, .. } => {
                Self::ensure_dense(comm, "bwd_single")?;
                Self::ensure_local(ctx, "bwd_single")?;
                let tok_buf = rt.buf_i32(tokens, &dims.tok())?;
                let tgt_buf = rt.buf_i32(targets, &dims.tok())?;
                let out = bwd
                    .run_b(&[p.xla()?, &tok_buf.0, &tgt_buf.0])
                    .context("single-stage bwd")?;
                Ok((to_f32(&out[0])?, scalar_f32(&out[1])?))
            }
            StageBackend::Builtin(st) => {
                Ok(st.bwd_single_ctx(comm, p.host()?, tokens, targets, ctx))
            }
        }
    }

    /// Last-stage backward: (stage input, targets) -> (grads, gx, loss).
    pub fn bwd_last(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        x: &[f32],
        targets: &[i32],
        dims: StageDims,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        self.bwd_last_ctx(rt, p, comm, x, targets, dims, &MoeFwdCtx::LOCAL)
    }

    /// [`Self::bwd_last`] with MoE wiring for the fused forward.
    pub fn bwd_last_ctx(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        x: &[f32],
        targets: &[i32],
        dims: StageDims,
        ctx: &MoeFwdCtx,
    ) -> Result<(Vec<f32>, Vec<f32>, f32)> {
        match &self.backend {
            StageBackend::Xla { bwd, .. } => {
                Self::ensure_dense(comm, "bwd_last")?;
                Self::ensure_local(ctx, "bwd_last")?;
                let x_buf = rt.buf_f32(x, &dims.act())?;
                let tgt_buf = rt.buf_i32(targets, &dims.tok())?;
                let out = bwd
                    .run_b(&[p.xla()?, &x_buf.0, &tgt_buf.0])
                    .context("last-stage bwd")?;
                Ok((to_f32(&out[0])?, to_f32(&out[1])?, scalar_f32(&out[2])?))
            }
            StageBackend::Builtin(st) => Ok(st.bwd_last_ctx(comm, p.host()?, x, targets, ctx)),
        }
    }

    /// First-stage backward: (tokens, upstream grad) -> grads.
    pub fn bwd_first(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        tokens: &[i32],
        gy: &[f32],
        dims: StageDims,
    ) -> Result<Vec<f32>> {
        match &self.backend {
            StageBackend::Xla { bwd, .. } => {
                Self::ensure_dense(comm, "bwd_first")?;
                let tok_buf = rt.buf_i32(tokens, &dims.tok())?;
                let gy_buf = rt.buf_f32(gy, &dims.act())?;
                let out = bwd
                    .run_b(&[p.xla()?, &tok_buf.0, &gy_buf.0])
                    .context("first-stage bwd")?;
                to_f32(&out[0])
            }
            StageBackend::Builtin(st) => Ok(st.bwd_first(comm, p.host()?, tokens, gy)),
        }
    }

    /// Middle-stage backward: (stage input, upstream grad) -> (grads, gx).
    pub fn bwd_mid(
        &self,
        rt: &Runtime,
        p: &ParamsHandle,
        comm: &TpComm,
        x: &[f32],
        gy: &[f32],
        dims: StageDims,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        match &self.backend {
            StageBackend::Xla { bwd, .. } => {
                Self::ensure_dense(comm, "bwd_mid")?;
                let x_buf = rt.buf_f32(x, &dims.act())?;
                let gy_buf = rt.buf_f32(gy, &dims.act())?;
                let out = bwd
                    .run_b(&[p.xla()?, &x_buf.0, &gy_buf.0])
                    .context("middle-stage bwd")?;
                Ok((to_f32(&out[0])?, to_f32(&out[1])?))
            }
            StageBackend::Builtin(st) => Ok(st.bwd_mid(comm, p.host()?, x, gy)),
        }
    }
}

/// A fully-loaded artifact bundle: meta + compiled stages.
pub struct Bundle {
    pub dir: PathBuf,
    pub meta: BundleMeta,
    pub stages: Vec<StageExecutables>,
}

impl Bundle {
    /// Load `artifacts/<name>` (meta.json + all stage executables).
    pub fn load(rt: &Runtime, dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let meta_path = dir.join("meta.json");
        let meta = BundleMeta::from_json(
            &std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {meta_path:?} — run `make artifacts`"))?,
        )
        .context("parsing meta.json")?;
        let mut stages = Vec::with_capacity(meta.stages.len());
        for sm in &meta.stages {
            stages.push(StageExecutables {
                meta: sm.clone(),
                backend: StageBackend::Xla {
                    init: rt.load(&dir.join(&sm.artifacts.init))?,
                    fwd: rt.load(&dir.join(&sm.artifacts.fwd))?,
                    bwd: rt.load(&dir.join(&sm.artifacts.bwd))?,
                },
            });
        }
        Ok(Self { dir, meta, stages })
    }

    /// Materialise a builtin bundle entirely in memory (no files, no PJRT).
    pub fn builtin(spec: &BuiltinSpec) -> Self {
        Self::builtin_with_policy(spec, crate::precision::CastPolicy::fp32())
    }

    /// Builtin bundle under an explicit cast policy — how the engine
    /// instantiates mixed-precision runs (`--precision bf16`): every
    /// stage stores params/activations/grads on the policy's grids; the
    /// collective wire dtype rides the engine's `TpComm`/bucket config.
    pub fn builtin_with_policy(
        spec: &BuiltinSpec,
        policy: crate::precision::CastPolicy,
    ) -> Self {
        Self::builtin_with(spec, policy, 1.25)
    }

    /// Builtin bundle under an explicit cast policy AND MoE capacity
    /// factor (the engine's `--capacity-factor`; ignored by dense
    /// bundles).
    pub fn builtin_with(
        spec: &BuiltinSpec,
        policy: crate::precision::CastPolicy,
        capacity_factor: f32,
    ) -> Self {
        let meta = BundleMeta::for_builtin(spec);
        let stages = meta
            .stages
            .iter()
            .map(|sm| StageExecutables {
                meta: sm.clone(),
                backend: StageBackend::Builtin(
                    BuiltinStage::dense(spec.clone(), sm.index as usize)
                        .with_policy(policy)
                        .with_capacity_factor(capacity_factor),
                ),
            })
            .collect();
        Self { dir: PathBuf::from("builtin"), meta, stages }
    }

    /// Activation/token shapes shared by every stage of this bundle.
    pub fn dims(&self) -> StageDims {
        StageDims {
            b: self.meta.mbs as usize,
            s: self.meta.model.seq as usize,
            d: self.meta.model.hidden as usize,
        }
    }

    /// Conventional bundle directory name.
    pub fn dir_name(model: &str, stages: u32, mbs: u32) -> String {
        format!("{model}-s{stages}-mb{mbs}")
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// u32 literal (PRNG keys).
pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Scalar f32 from a rank-0 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_bundle_shape() {
        let spec = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
        let b = Bundle::builtin(&spec);
        assert_eq!(b.meta.n_stages, 4);
        assert_eq!(b.stages.len(), 4);
        assert!(b.stages[0].meta.has_embed && !b.stages[0].meta.has_head);
        assert!(b.stages[3].meta.has_head && !b.stages[3].meta.has_embed);
        let sum: u64 = b.meta.stages.iter().map(|s| s.param_count).sum();
        assert_eq!(sum, b.meta.model.total_params);
        assert_eq!(b.dims().b, 2);
    }

    #[test]
    fn builtin_stage_contract_round_trip() {
        // drive the typed contract end to end on the builtin backend with
        // a null runtime (no PJRT anywhere)
        let spec = BuiltinSpec::parse("builtin:tiny-s2-mb1").unwrap();
        let bundle = Bundle::builtin(&spec);
        let rt = Runtime::null();
        let comm = TpComm::solo();
        assert_eq!(rt.platform(), "builtin");
        let dims = bundle.dims();
        let t = dims.b * dims.s;
        let tokens: Vec<i32> = (0..t).map(|i| (i % spec.vocab) as i32).collect();
        let targets: Vec<i32> = (0..t).map(|i| ((i + 1) % spec.vocab) as i32).collect();

        let p0 = bundle.stages[0].init_params(1).unwrap();
        let p1 = bundle.stages[1].init_params(1).unwrap();
        let h0 = bundle.stages[0].prepare_params(&rt, &p0).unwrap();
        let h1 = bundle.stages[1].prepare_params(&rt, &p1).unwrap();

        let y = bundle.stages[0].fwd_first(&rt, &h0, &comm, &tokens, dims).unwrap();
        assert_eq!(y.len(), t * dims.d);
        let (g1, gx, loss) =
            bundle.stages[1].bwd_last(&rt, &h1, &comm, &y, &targets, dims).unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        assert_eq!(g1.len(), p1.len());
        let g0 = bundle.stages[0].bwd_first(&rt, &h0, &comm, &tokens, &gx, dims).unwrap();
        assert_eq!(g0.len(), p0.len());
    }

    #[test]
    fn tp_shard_views_builtin_stages() {
        let spec = BuiltinSpec::parse("builtin:tiny-s2-mb1").unwrap();
        let bundle = Bundle::builtin(&spec);
        for tp in [2usize, 4] {
            let mut total = 0u64;
            for r in 0..tp {
                let shard = bundle.stages[0].tp_shard(tp, r).unwrap();
                assert_eq!(shard.meta.param_count, spec.shard_stage_params(0, tp) as u64);
                assert!(shard.tp_replicated_span().is_some());
                total += shard.meta.param_count;
            }
            // shards overcount the dense stage by the replicated b2 copies
            let extra = ((tp - 1) * spec.hidden) as u64;
            assert_eq!(total, spec.stage_params(0) as u64 + extra);
        }
        // tp must slice hidden/vocab
        assert!(bundle.stages[0].tp_shard(3, 0).is_err());
        // dense stages report no replicated span
        assert!(bundle.stages[0].tp_replicated_span().is_none());
    }

    #[test]
    fn null_runtime_rejects_xla_paths() {
        let rt = Runtime::null();
        assert!(rt.buf_f32(&[1.0], &[1]).is_err());
        assert!(rt.load(Path::new("nope.hlo")).is_err());
    }
}
