//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! This is the only boundary between the rust coordinator and the
//! JAX/Pallas compute: `make artifacts` ran Python once; from here on the
//! stage graphs are opaque compiled executables on the PJRT CPU client
//! (`PjRtClient::cpu` -> `HloModuleProto::from_text_file` ->
//! `client.compile` -> `execute`).  HLO *text* is the interchange format —
//! jax >= 0.5 serialises protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// meta.json emitted by `python/compile/aot.py` for one artifact bundle.
#[derive(Debug, Clone)]
pub struct BundleMeta {
    pub model: ModelMeta,
    pub n_stages: u32,
    pub mbs: u32,
    pub use_flash: bool,
    pub use_fused_xent: bool,
    pub tokens_per_microbatch: u64,
    pub flops_per_microbatch: f64,
    pub stages: Vec<StageMeta>,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub n_layers: u32,
    pub hidden: u64,
    pub n_heads: u32,
    pub vocab: u64,
    pub seq: u64,
    pub total_params: u64,
}

#[derive(Debug, Clone)]
pub struct StageMeta {
    pub index: u32,
    pub layer_start: u32,
    pub layer_end: u32,
    pub has_embed: bool,
    pub has_head: bool,
    pub param_count: u64,
    pub artifacts: StageArtifacts,
}

#[derive(Debug, Clone)]
pub struct StageArtifacts {
    pub init: String,
    pub fwd: String,
    pub bwd: String,
}

impl BundleMeta {
    /// Parse the aot.py meta.json (in-tree JSON parser; offline build).
    pub fn from_json(src: &str) -> Result<Self> {
        let j = Json::parse(src).map_err(|e| anyhow!("{e}"))?;
        let m = j.field("model").map_err(|e| anyhow!("{e}"))?;
        let model = ModelMeta {
            name: m.str_field("name").map_err(|e| anyhow!("{e}"))?,
            n_layers: m.u64_field("n_layers").map_err(|e| anyhow!("{e}"))? as u32,
            hidden: m.u64_field("hidden").map_err(|e| anyhow!("{e}"))?,
            n_heads: m.u64_field("n_heads").map_err(|e| anyhow!("{e}"))? as u32,
            vocab: m.u64_field("vocab").map_err(|e| anyhow!("{e}"))?,
            seq: m.u64_field("seq").map_err(|e| anyhow!("{e}"))?,
            total_params: m.u64_field("total_params").map_err(|e| anyhow!("{e}"))?,
        };
        let stages_json = j
            .field("stages")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("stages must be an array"))?;
        let mut stages = Vec::with_capacity(stages_json.len());
        for s in stages_json {
            let a = s.field("artifacts").map_err(|e| anyhow!("{e}"))?;
            stages.push(StageMeta {
                index: s.u64_field("index").map_err(|e| anyhow!("{e}"))? as u32,
                layer_start: s.u64_field("layer_start").map_err(|e| anyhow!("{e}"))? as u32,
                layer_end: s.u64_field("layer_end").map_err(|e| anyhow!("{e}"))? as u32,
                has_embed: s.bool_field("has_embed").map_err(|e| anyhow!("{e}"))?,
                has_head: s.bool_field("has_head").map_err(|e| anyhow!("{e}"))?,
                param_count: s.u64_field("param_count").map_err(|e| anyhow!("{e}"))?,
                artifacts: StageArtifacts {
                    init: a.str_field("init").map_err(|e| anyhow!("{e}"))?,
                    fwd: a.str_field("fwd").map_err(|e| anyhow!("{e}"))?,
                    bwd: a.str_field("bwd").map_err(|e| anyhow!("{e}"))?,
                },
            });
        }
        Ok(BundleMeta {
            model,
            n_stages: j.u64_field("n_stages").map_err(|e| anyhow!("{e}"))? as u32,
            mbs: j.u64_field("mbs").map_err(|e| anyhow!("{e}"))? as u32,
            use_flash: j.bool_field("use_flash").map_err(|e| anyhow!("{e}"))?,
            use_fused_xent: j.bool_field("use_fused_xent").map_err(|e| anyhow!("{e}"))?,
            tokens_per_microbatch: j
                .u64_field("tokens_per_microbatch")
                .map_err(|e| anyhow!("{e}"))?,
            flops_per_microbatch: j
                .f64_field("flops_per_microbatch")
                .map_err(|e| anyhow!("{e}"))?,
            stages,
        })
    }
}

/// A compiled executable, shareable across worker threads.
///
/// SAFETY: the `xla` crate wraps raw pointers (hence `!Send`), but XLA's
/// `PjRtClient` and `PjRtLoadedExecutable` are documented thread-safe
/// (execution is internally synchronised per device).  We share only the
/// client and executables; `Literal`s and `PjRtBuffer`s stay thread-local.
///
/// NOTE on `execute` vs `execute_b`: the published xla crate's `execute`
/// entry point (xla_rs.cc) uploads every input literal to a fresh device
/// buffer and `release()`s it without ever freeing — every call leaks all
/// inputs.  We therefore ALWAYS go through `execute_b` with buffers this
/// wrapper owns (freed by `PjRtBuffer::drop`), which also lets the hot
/// path upload the big parameter buffer once per step instead of once per
/// micro-batch.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
}

unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with device-buffer inputs (the hot path); flattens the
    /// 1-element replica dim and unpacks the output tuple (aot.py lowers
    /// with `return_tuple=True`).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let mut out = self.exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let first = out
            .pop()
            .and_then(|mut d| if d.is_empty() { None } else { Some(d.swap_remove(0)) })
            .ok_or_else(|| anyhow!("executable produced no outputs"))?;
        let lit = first.to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Execute with literal inputs: uploads to owned device buffers first
    /// (see the leak note above), then defers to [`Executable::run_b`].
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs: Vec<xla::PjRtBuffer> = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<_, _>>()?;
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        self.run_b(&refs)
    }
}

/// The PJRT client plus helpers; one per process, shared by all workers.
pub struct Runtime {
    client: xla::PjRtClient,
}

unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Arc<Self>> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Arc::new(Self { client }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text artifact and compile it.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe, client: self.client.clone() })
    }

    /// Upload an f32 host slice to an owned device buffer.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Upload an i32 host slice to an owned device buffer.
    pub fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client.buffer_from_host_buffer(data, dims, None)?))
    }

    /// Upload a u32 host slice to an owned device buffer.
    pub fn buf_u32(&self, data: &[u32], dims: &[usize]) -> Result<OwnedBuffer> {
        Ok(OwnedBuffer(self.client.buffer_from_host_buffer(data, dims, None)?))
    }
}

/// A device buffer owned by a single worker thread.  The `xla` wrapper
/// type is `!Send` only because of its raw pointer; PJRT CPU buffers are
/// safe to move between threads as long as use is externally synchronised,
/// which the engine guarantees (each buffer is created, used and dropped
/// by one worker).
pub struct OwnedBuffer(pub xla::PjRtBuffer);

unsafe impl Send for OwnedBuffer {}

/// One pipeline stage's compiled entry points.
pub struct StageExecutables {
    pub meta: StageMeta,
    pub init: Executable,
    pub fwd: Executable,
    pub bwd: Executable,
}

/// A fully-loaded artifact bundle: meta + compiled stages.
pub struct Bundle {
    pub dir: PathBuf,
    pub meta: BundleMeta,
    pub stages: Vec<StageExecutables>,
}

impl Bundle {
    /// Load `artifacts/<name>` (meta.json + all stage executables).
    pub fn load(rt: &Runtime, dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        let meta_path = dir.join("meta.json");
        let meta = BundleMeta::from_json(
            &std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {meta_path:?} — run `make artifacts`"))?,
        )
        .context("parsing meta.json")?;
        let mut stages = Vec::with_capacity(meta.stages.len());
        for sm in &meta.stages {
            stages.push(StageExecutables {
                meta: sm.clone(),
                init: rt.load(&dir.join(&sm.artifacts.init))?,
                fwd: rt.load(&dir.join(&sm.artifacts.fwd))?,
                bwd: rt.load(&dir.join(&sm.artifacts.bwd))?,
            });
        }
        Ok(Self { dir, meta, stages })
    }

    /// Conventional bundle directory name.
    pub fn dir_name(model: &str, stages: u32, mbs: u32) -> String {
        format!("{model}-s{stages}-mb{mbs}")
    }
}

// ---------------------------------------------------------------------------
// literal helpers
// ---------------------------------------------------------------------------

/// f32 literal of the given shape.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// i32 literal of the given shape.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// u32 literal (PRNG keys).
pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape {dims:?} != len {}", data.len());
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

/// Scalar f32 from a rank-0 literal.
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}
