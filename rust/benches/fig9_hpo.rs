//! Bench: Figure 9 — the DeepHyper-style search trajectory.
//!
//! Shape contracts: (a) OOM failures present but tapering over the
//! trajectory, (b) the best-so-far objective is monotone and ends well
//! above the random-warmup best.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::hpo::{self, SearchConfig};
use frontier_llm::perf::PerfModel;

fn main() {
    header("Fig 9: Bayesian search over Table IV (175B, 12-16 nodes)");
    let perf = PerfModel::default();
    let cfg = SearchConfig { n_evals: 128, n_init: 24, n_candidates: 256, seed: 7 };
    let result = hpo::run_search(&perf, &cfg);

    // condensed trajectory print (every 8th eval + all failures)
    for (i, ev) in result.evals.iter().enumerate() {
        if i % 16 == 0 {
            println!(
                "#{i:>3}: best so far {:>6.1} TFLOPS/GPU   (this: {})",
                result.best_trajectory[i],
                ev.objective
                    .map(|v| format!("{v:.1}"))
                    .unwrap_or_else(|| "OOM".into())
            );
        }
    }
    let q = result.failures_by_quarter();
    println!("failures by quarter: {q:?}  total {}", result.n_failures());
    assert!(result.n_failures() > 0, "space must contain OOMs");
    assert!(q[0] >= q[3], "failures must taper: {q:?}");
    let warmup_best = result.best_trajectory[cfg.n_init as usize - 1];
    let final_best = *result.best_trajectory.last().unwrap();
    println!("best: warmup {warmup_best:.1} -> final {final_best:.1} TFLOPS/GPU");
    assert!(final_best >= warmup_best);
    println!("[shape OK: tapering failures, improving best]");

    bench("fig9::single_evaluation", 100, 5000, || {
        let p = frontier_llm::hpo::space::Point {
            pp: 16,
            tp: 4,
            mbs: 8,
            gas: 10,
            zero_stage: frontier_llm::zero::ShardingStage::OptimizerStates,
            nnodes: 16,
            interleave: 1,
            bf16: true,
            zero3_prefetch: 1,
            experts: 1,
        };
        std::hint::black_box(hpo::evaluate_point(&perf, &p));
    });
    bench("fig9::full_search_64_evals", 0, 3, || {
        let cfg = SearchConfig { n_evals: 64, n_init: 16, n_candidates: 128, seed: 3 };
        std::hint::black_box(hpo::run_search(&perf, &cfg));
    });

    write_report();
}
