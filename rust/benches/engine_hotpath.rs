//! Bench: the REAL execution engine's hot paths (EXPERIMENTS.md §Perf).
//!
//! Times the pieces that sit on the training step's critical path:
//! the builtin blocked matmul kernels against the naive pre-PR loops
//! (the ≥3× kernel contract at d=256), collectives (ring vs naive vs
//! nonblocking-bucketed all-reduce at gradient-buffer sizes), the
//! sharded Adam step, schedule generation, overlapped-vs-sequential DP
//! gradient sync through the engine, the sync-vs-async checkpoint save
//! path (exposed save time must shrink under --async-checkpoint), and a
//! short end-to-end training run over the AOT artifacts.
//!
//! Every section lands in `BENCH_engine.json` (via `bench_util`), so
//! the kernel baseline (`kernel::*_naive`) and the blocked numbers are
//! recorded side by side each run.  Set `HOTPATH_SMOKE=1` for the CI
//! smoke: small collective/engine sizes, few iterations.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, record_meta, write_report};

use std::sync::Arc;
use std::thread;

use frontier_llm::collectives::{chunk_bounds, Algo, Group, NodeMap};
use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train_with_bundle, EngineConfig};
use frontier_llm::optim::{clip_grad_norm, Adam, AdamConfig};
use frontier_llm::precision::{Dtype, GradWire};
use frontier_llm::runtime::kernels;
use frontier_llm::runtime::{Bundle, BuiltinSpec, BuiltinStage, Runtime};
use frontier_llm::schedule;
use frontier_llm::zero::ShardingStage;

fn bench_allreduce(n_ranks: usize, len: usize, algo: Algo, label: &str) {
    // spawn ranks once; each iteration is one collective round
    let group = Group::new(n_ranks);
    bench(label, 2, 20, || {
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    g.all_reduce_sum(rank, &mut buf, algo);
                    std::hint::black_box(buf[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Partition-aligned nonblocking reduce-scatter: every rank launches one
/// bucket per owner partition and drains them, the owner alone
/// materialising its reduced shard — the ZeRO-2/3 gradient primitive.
fn bench_reduce_scatter(n_ranks: usize, len: usize, label: &str) {
    let group = Group::new(n_ranks);
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let bounds = chunk_bounds(len, g.len());
                    let started: Vec<_> = bounds
                        .iter()
                        .enumerate()
                        .map(|(owner, &(lo, hi))| {
                            g.start_reduce_scatter_dtype(
                                rank,
                                (round << 8) | owner as u64,
                                vec![1.0f32; hi - lo],
                                owner,
                                Dtype::F32,
                            )
                        })
                        .collect();
                    for h in started {
                        std::hint::black_box(h.wait());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Nonblocking parameter all-gather: every rank deposits its shard and
/// redeems the assembled full buffer — ZeRO-3's on-demand gather.
fn bench_all_gather(n_ranks: usize, total: usize, label: &str) {
    let group = Group::new(n_ranks);
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let (lo, hi) = chunk_bounds(total, g.len())[rank];
                    let h = g.start_all_gather_dtype(
                        rank,
                        round,
                        vec![1.0f32; hi - lo],
                        total,
                        Dtype::F32,
                    );
                    std::hint::black_box(h.wait()[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Nonblocking bucketed all-reduce: every rank launches `n_buckets`
/// then drains them — the engine's overlapped grad-sync primitive.
fn bench_bucketed(n_ranks: usize, len: usize, n_buckets: u64, label: &str) {
    let group = Group::new(n_ranks);
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let per = len / n_buckets as usize;
                    let started: Vec<_> = (0..n_buckets)
                        .map(|b| g.start_all_reduce(rank, (round << 8) | b, vec![1.0f32; per]))
                        .collect();
                    for h in started {
                        std::hint::black_box(h.wait()[0]);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Packed node placement for a bench group: first `ceil(n / nodes)`
/// ranks on node 0, and so on — the same shape `EngineConfig::nodes`
/// induces through `Machine`.
fn packed(n: usize, nodes: usize) -> NodeMap {
    let per = n.div_ceil(nodes);
    let assignment: Vec<usize> = (0..n).map(|r| r / per).collect();
    NodeMap::new(&assignment)
}

/// Two-tier partition-aligned reduce-scatter (ZeRO-2/3 grad sync over
/// the hierarchical path), optionally on the int8 inter-node wire.
fn bench_reduce_scatter_hier(
    n_ranks: usize,
    nodes: usize,
    len: usize,
    grad_wire: GradWire,
    label: &str,
) {
    let group = Group::new_with_nodes(n_ranks, Some(packed(n_ranks, nodes)));
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let bounds = chunk_bounds(len, g.len());
                    let started: Vec<_> = bounds
                        .iter()
                        .enumerate()
                        .map(|(owner, &(lo, hi))| {
                            g.start_reduce_scatter_hier(
                                rank,
                                (round << 8) | owner as u64,
                                vec![1.0f32; hi - lo],
                                owner,
                                Dtype::F32,
                                grad_wire,
                            )
                        })
                        .collect();
                    for h in started {
                        std::hint::black_box(h.wait());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Two-tier primary parameter all-gather (ZeRO-3's hierarchical
/// on-demand gather).
fn bench_all_gather_hier(n_ranks: usize, nodes: usize, total: usize, label: &str) {
    let group = Group::new_with_nodes(n_ranks, Some(packed(n_ranks, nodes)));
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let (lo, hi) = chunk_bounds(total, g.len())[rank];
                    let h = g.start_all_gather_hier(
                        rank,
                        round,
                        Arc::new(vec![1.0f32; hi - lo]),
                        total,
                        Dtype::F32,
                    );
                    std::hint::black_box(h.wait()[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

/// Deterministic dtype-packed all-to-all: every rank deposits one part
/// per destination and redeems its receive set — the MoE dispatch (and,
/// mirrored, combine) wire.  `part_len` is the per-destination element
/// count, so one round moves `n² × part_len` elements group-wide.
fn bench_all_to_all(n_ranks: usize, part_len: usize, wire: Dtype, label: &str) {
    let group = Group::new(n_ranks);
    let mut round = 0u64;
    bench(label, 2, 20, || {
        round += 1;
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let parts: Vec<Vec<f32>> =
                        (0..g.len()).map(|dst| vec![(rank + dst) as f32; part_len]).collect();
                    std::hint::black_box(g.all_to_all(rank, round, parts, wire));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn fill(seed: usize, len: usize) -> Vec<f32> {
    (0..len).map(|i| ((seed * 31 + i) as f32 * 0.05).sin()).collect()
}

/// THE kernel contract bench: one linear layer's fwd + bwd worth of
/// matmuls (y = xW, dW = xᵀdy, dx = dyWᵀ) at d≥256, blocked vs the
/// pre-PR naive loops.  `BENCH_engine.json` records both sections, so
/// the ≥3× acceptance check is self-contained in one run.
fn bench_linear_kernels(iters: u32) {
    let (t, d) = (256usize, 256usize);
    header("builtin kernels: linear fwd+bwd at t=256, d=256 (blocked vs naive baseline)");
    let x = fill(1, t * d);
    let w = fill(2, d * d);
    let dy = fill(3, t * d);
    let mut h = vec![0.0f32; t * d];
    let mut gw = vec![0.0f32; d * d];
    let mut dx = vec![0.0f32; t * d];

    let naive = bench("kernel::linear_fwdbwd_d256_naive", 1, iters, || {
        kernels::naive::matmul_acc(&mut h, &x, &w, t, d, d);
        kernels::naive::matmul_at_acc(&mut gw, &x, &dy, t, d, d);
        kernels::naive::matmul_bt_acc(&mut dx, &dy, &w, t, d, d);
        std::hint::black_box((h[0], gw[0], dx[0]));
    });
    h.iter_mut().chain(gw.iter_mut()).chain(dx.iter_mut()).for_each(|v| *v = 0.0);
    let blocked = bench("kernel::linear_fwdbwd_d256_blocked", 1, iters, || {
        kernels::matmul_acc(&mut h, &x, &w, t, d, d);
        kernels::matmul_at_acc(&mut gw, &x, &dy, t, d, d);
        kernels::matmul_bt_acc(&mut dx, &dy, &w, t, d, d);
        std::hint::black_box((h[0], gw[0], dx[0]));
    });
    println!(
        "[kernel speedup at d=256: {:.2}x (contract: >= 3x)]",
        naive.mean_s / blocked.mean_s
    );
    // bf16-in/f32-acc path: same blocked loops behind an input cast (the
    // software-emulation overhead is the quantize pass; recorded so the
    // fp32/bf16 pair rides BENCH_engine.json side by side)
    h.iter_mut().chain(gw.iter_mut()).chain(dx.iter_mut()).for_each(|v| *v = 0.0);
    bench("kernel::linear_fwdbwd_d256_bf16", 1, iters, || {
        kernels::bf16::matmul_acc(&mut h, &x, &w, t, d, d);
        kernels::bf16::matmul_at_acc(&mut gw, &x, &dy, t, d, d);
        kernels::bf16::matmul_bt_acc(&mut dx, &dy, &w, t, d, d);
        std::hint::black_box((h[0], gw[0], dx[0]));
    });
}

/// The same contract through the real stage entry points: a pure MLP
/// block (no embed/head) of a d=256 builtin model, fwd + bwd.
fn bench_builtin_block(iters: u32) {
    header("builtin stage: block fwd+bwd through the stage contract (d=256)");
    let spec = BuiltinSpec {
        name: "bench".into(),
        vocab: 512,
        hidden: 256,
        seq: 64,
        mbs: 4,
        n_stages: 3,
        experts: 1,
        topk: 1,
        moe: false,
    };
    let st = BuiltinStage::dense(spec, 1); // middle stage: pure block
    let comm = frontier_llm::collectives::TpComm::solo();
    let params = st.init(7);
    let t = 4 * 64;
    let x = fill(4, t * 256);
    let gy = fill(5, t * 256);
    bench("builtin::block_fwd_d256", 1, iters, || {
        std::hint::black_box(st.fwd_mid(&comm, &params, &x));
    });
    bench("builtin::block_bwd_d256", 1, iters, || {
        std::hint::black_box(st.bwd_mid(&comm, &params, &x, &gy));
    });
}

fn main() {
    // smoke = small collective/optimizer sizes for the CI hotpath check;
    // size-dependent section names carry the actual size so smoke runs
    // never masquerade as full-size baselines in BENCH_engine.json
    let smoke = std::env::var("HOTPATH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false);
    // precision modes this bench run covers (kernel + engine sections)
    record_meta("precision", "fp32+bf16");
    let ar_len = if smoke { 1 << 16 } else { 4 << 20 };
    let sz = if smoke { "256KB" } else { "16MB" };
    let sz4 = if smoke { "64KB" } else { "4MB" };
    let kern_iters = if smoke { 5 } else { 20 };

    bench_linear_kernels(kern_iters);
    bench_builtin_block(kern_iters);

    header("collectives: 4-rank all-reduce of a grad buffer (blocking + bucketed)");
    bench_allreduce(4, ar_len, Algo::Ring, &format!("collectives::ring_4x{sz}"));
    bench_allreduce(4, ar_len, Algo::Naive, &format!("collectives::naive_4x{sz}"));
    bench_allreduce(2, ar_len / 4, Algo::Ring, &format!("collectives::ring_2x{sz4}"));
    bench_bucketed(4, ar_len, 4, &format!("collectives::nb_bucketed_4x{sz}_b4"));

    header("collectives: ZeRO wire primitives (reduce-scatter + param all-gather)");
    bench_reduce_scatter(4, ar_len, &format!("collectives::reduce_scatter_4x{sz}"));
    bench_all_gather(4, ar_len, &format!("collectives::param_all_gather_4x{sz}"));

    header("collectives: hierarchical (2-node) ZeRO primitives, flat counterparts above");
    bench_reduce_scatter_hier(
        4,
        2,
        ar_len,
        GradWire::F32,
        &format!("collectives::hier_reduce_scatter_4x{sz}_n2"),
    );
    bench_reduce_scatter_hier(
        4,
        2,
        ar_len,
        GradWire::Int8,
        &format!("collectives::hier_reduce_scatter_4x{sz}_n2_int8"),
    );
    bench_all_gather_hier(4, 2, ar_len, &format!("collectives::hier_param_all_gather_4x{sz}_n2"));

    header("collectives: expert-parallel all-to-all (MoE dispatch/combine wire)");
    // per-destination parts sized like a routed expert buffer; the bf16
    // row rides the packed-u16 wire (half the bytes through the mailbox)
    let a2a_part = if smoke { 1 << 12 } else { 1 << 16 };
    let a2a_sz = if smoke { "16KB" } else { "256KB" };
    bench_all_to_all(4, a2a_part, Dtype::F32, &format!("collectives::all_to_all_4x{a2a_sz}"));
    bench_all_to_all(4, a2a_part, Dtype::Bf16, &format!("collectives::all_to_all_4x{a2a_sz}_bf16"));
    bench_all_to_all(2, a2a_part, Dtype::F32, &format!("collectives::all_to_all_2x{a2a_sz}"));

    header("optimizer: Adam step + grad clip");
    let n = if smoke { 1 << 16 } else { 4 << 20 };
    let nm = if smoke { "64K" } else { "4M" };
    let mut params = vec![0.1f32; n];
    let mut grads = vec![0.01f32; n];
    let mut adam = Adam::new(AdamConfig::default(), n);
    bench(&format!("optim::adam_step_{nm}"), 2, 20, || {
        adam.step(&mut params, &grads, 1.0);
        std::hint::black_box(params[0]);
    });
    bench(&format!("optim::grad_clip_{nm}"), 2, 50, || {
        std::hint::black_box(clip_grad_norm(&mut grads, 1e9));
    });

    header("schedule generation");
    bench("schedule::one_f1b_p64_m1600", 10, 200, || {
        std::hint::black_box(schedule::one_f1b(64, 1600));
    });
    bench("schedule::interleaved_p64_m1600_v4", 10, 200, || {
        std::hint::black_box(schedule::interleaved_1f1b(64, 1600, 4));
    });
    bench("schedule::validate_p16_m128", 10, 200, || {
        let s = schedule::one_f1b(16, 128);
        std::hint::black_box(s.validate().unwrap());
    });
    bench("schedule::validate_interleaved_p16_m128_v4", 10, 100, || {
        let s = schedule::interleaved_1f1b(16, 128, 4);
        std::hint::black_box(s.validate().unwrap());
    });

    header("end-to-end engine: builtin tiny model, 4 stages, 3 steps");
    for (label, sched) in [
        ("engine::train_builtin_1f1b_pp4", ScheduleKind::OneF1B),
        ("engine::train_builtin_interleaved_v2", ScheduleKind::Interleaved1F1B { v: 2 }),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 1,
            schedule: sched,
            microbatches: 4,
            steps: 3,
            ..Default::default()
        };
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: DP grad sync, overlapped vs sequential (dp=2, v=2)");
    for (label, overlap, precision) in [
        ("engine::train_dp2_overlap", true, frontier_llm::precision::Dtype::F32),
        ("engine::train_dp2_sequential", false, frontier_llm::precision::Dtype::F32),
        // bf16 bucket sync: packed-u16 deposits, half the wire bytes
        ("engine::train_dp2_overlap_bf16", true, frontier_llm::precision::Dtype::Bf16),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::Interleaved1F1B { v: 2 },
            microbatches: 4,
            steps: 3,
            overlap_grad_sync: overlap,
            grad_bucket_floats: 256,
            precision,
            ..Default::default()
        };
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: sharded DP stages (zero2 reduce-scatter, zero3 gather)");
    for (label, stage) in [
        ("engine::train_dp2_zero2", ShardingStage::Gradients),
        ("engine::train_dp2_zero3", ShardingStage::Parameters),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::Interleaved1F1B { v: 2 },
            microbatches: 4,
            steps: 3,
            zero_stage: stage,
            grad_bucket_floats: 256,
            ..Default::default()
        };
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: hierarchical DP (2 nodes) + quantized grad wire");
    for (label, stage, wire) in [
        ("engine::train_dp2_zero2_hier_n2", ShardingStage::Gradients, None),
        ("engine::train_dp2_zero3_hier_n2", ShardingStage::Parameters, None),
        ("engine::train_dp2_zero2_hier_n2_int8", ShardingStage::Gradients, Some(GradWire::Int8)),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::Interleaved1F1B { v: 2 },
            microbatches: 4,
            steps: 3,
            zero_stage: stage,
            grad_bucket_floats: 256,
            nodes: 2,
            grad_wire: wire,
            ..Default::default()
        };
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: zero3 prefetch depth, residency vs exposure");
    for prefetch in [0usize, 1, 3] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::Interleaved1F1B { v: 2 },
            microbatches: 4,
            steps: 3,
            zero_stage: ShardingStage::Parameters,
            grad_bucket_floats: 256,
            zero3_prefetch: prefetch,
            ..Default::default()
        };
        // the residency half of the trade-off: peak gathered floats at
        // this lookahead depth (the (N+1)-chunk transient), recorded
        // next to the timing so BENCH_engine.json carries the measured
        // residency-vs-exposure line in one run
        let peak = frontier_llm::coordinator::train(&cfg).unwrap().zero3_peak_gathered_floats;
        record_meta(
            &format!("zero3_prefetch{prefetch}_peak_gathered_floats"),
            &peak.to_string(),
        );
        println!("  prefetch {prefetch}: peak gathered floats {peak}");
        bench(&format!("engine::train_dp2_zero3_prefetch{prefetch}"), 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: checkpoint save path, sync vs async (dp=2, every step)");
    // the crash-consistency acceptance number: with --async-checkpoint
    // the step loop only pays the barrier + in-memory snapshot, while
    // the writes drain on the saver thread — exposed save time must be
    // strictly below the sync path's (which pays the whole write inline)
    let ckpt_root = std::env::temp_dir().join(format!("fllm-hotpath-ckpt-{}", std::process::id()));
    let mut ckpt_exposed = [0.0f64; 2];
    for (i, (label, key, async_ckpt)) in [
        ("engine::train_dp2_ckpt_sync", "sync", false),
        ("engine::train_dp2_ckpt_async", "async", true),
    ]
    .into_iter()
    .enumerate()
    {
        let dir = ckpt_root.join(key);
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::Interleaved1F1B { v: 2 },
            microbatches: 4,
            steps: 3,
            checkpoint_dir: Some(dir.clone()),
            checkpoint_every: 1,
            async_checkpoint: async_ckpt,
            ..Default::default()
        };
        let (mut exposed_acc, mut hidden_acc, mut runs) = (0.0f64, 0.0f64, 0u32);
        bench(label, 1, 5, || {
            let _ = std::fs::remove_dir_all(&dir);
            let r = frontier_llm::coordinator::train(&cfg).unwrap();
            exposed_acc += r.ckpt_save_exposed_ms;
            hidden_acc += r.ckpt_save_hidden_ms;
            runs += 1;
            std::hint::black_box(r.final_loss());
        });
        ckpt_exposed[i] = exposed_acc / runs as f64;
        record_meta(&format!("ckpt_{key}_exposed_ms"), &format!("{:.3}", ckpt_exposed[i]));
        record_meta(&format!("ckpt_{key}_hidden_ms"), &format!("{:.3}", hidden_acc / runs as f64));
    }
    let _ = std::fs::remove_dir_all(&ckpt_root);
    println!(
        "[ckpt exposed save time per run: sync {:.2} ms vs async {:.2} ms \
         (contract: async < sync)]",
        ckpt_exposed[0], ckpt_exposed[1]
    );

    header("end-to-end engine: tensor-parallel builtin (tp2 x pp4)");
    {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 1,
            tp: 2,
            schedule: ScheduleKind::OneF1B,
            microbatches: 4,
            steps: 3,
            ..Default::default()
        };
        bench("engine::train_builtin_tp2_pp4", 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: MoE stages (4 experts, top-2), local vs expert-parallel");
    for (label, ep) in [
        // ep=1 computes every expert locally (no wire); ep=2 shards the
        // expert FLOPs over the a2a — the pair is the routed-wire cost
        ("engine::train_moe4k2_ep1", 1usize),
        ("engine::train_moe4k2_ep2", 2usize),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-moe4k2-s2-mb2".into(),
            dp: 2,
            ep,
            schedule: ScheduleKind::OneF1B,
            microbatches: 4,
            steps: 3,
            ..Default::default()
        };
        let report = frontier_llm::coordinator::train(&cfg).unwrap();
        record_meta(
            &format!("moe_ep{ep}_a2a_payload_bytes"),
            &report.moe_a2a_payload_bytes.to_string(),
        );
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: tracer overhead, traced vs untraced (dp=2, v=2)");
    // the zero-overhead-when-off contract, measured: the traced run
    // records every span AND writes the merged Chrome trace + per-step
    // JSONL each iteration, and must still land within 3% of the
    // untraced wall time; the traced run's summary also stamps the
    // audit's dimensionless terms (dp overlap, bubble fraction) into
    // BENCH_engine.json meta next to the engine's own numbers
    let trace_root =
        std::env::temp_dir().join(format!("fllm-hotpath-trace-{}", std::process::id()));
    let untraced_cfg = EngineConfig {
        bundle: "builtin:tiny-s4-mb2".into(),
        dp: 2,
        schedule: ScheduleKind::Interleaved1F1B { v: 2 },
        microbatches: 4,
        steps: 3,
        grad_bucket_floats: 256,
        ..Default::default()
    };
    let traced_cfg = EngineConfig {
        trace_out: Some(trace_root.join("trace.json")),
        metrics_jsonl: Some(trace_root.join("metrics.jsonl")),
        ..untraced_cfg.clone()
    };
    let untraced = bench("engine::train_dp2_untraced", 1, 5, || {
        std::hint::black_box(frontier_llm::coordinator::train(&untraced_cfg).unwrap());
    });
    let mut traced_report = None;
    let traced = bench("engine::train_dp2_traced", 1, 5, || {
        traced_report = Some(frontier_llm::coordinator::train(&traced_cfg).unwrap());
    });
    let tracer_overhead_pct = 100.0 * (traced.mean_s / untraced.mean_s - 1.0);
    record_meta("tracer_overhead_pct", &format!("{tracer_overhead_pct:.2}"));
    if let Some(ts) = traced_report.as_ref().and_then(|r| r.trace_summary.as_ref()) {
        record_meta("trace_dp_overlap", &format!("{:.4}", ts.dp_overlap));
        record_meta("trace_bubble_fraction", &format!("{:.4}", ts.bubble_fraction));
        record_meta("trace_max_busy_over_wall", &format!("{:.4}", ts.max_busy_over_wall));
    }
    let _ = std::fs::remove_dir_all(&trace_root);
    println!("[tracer overhead: {tracer_overhead_pct:.2}% (contract: < 3%)]");

    header("end-to-end engine: tiny GPT artifacts, 2-stage pipeline x dp2");
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::cpu() {
        Ok(rt) if root.join("tiny-s2-mb2/meta.json").exists() => {
            let bundle = Arc::new(Bundle::load(&rt, root.join("tiny-s2-mb2")).unwrap());
            let cfg = EngineConfig {
                artifacts_root: root,
                bundle: "tiny-s2-mb2".into(),
                dp: 2,
                schedule: ScheduleKind::OneF1B,
                microbatches: 4,
                steps: 3,
                zero_stage: ShardingStage::OptimizerStates,
                ..Default::default()
            };
            bench("engine::train_3steps_tiny_pp2dp2", 1, 5, || {
                std::hint::black_box(
                    train_with_bundle(&cfg, rt.clone(), bundle.clone()).unwrap(),
                );
            });
        }
        Ok(_) => println!("(artifacts missing — run `make artifacts` to include the engine bench)"),
        Err(_) => println!("(no PJRT client in this build — artifact engine bench skipped)"),
    }

    write_report();
}
