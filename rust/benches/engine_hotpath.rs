//! Bench: the REAL execution engine's hot paths (EXPERIMENTS.md §Perf).
//!
//! Times the pieces that sit on the training step's critical path:
//! collectives (ring vs naive all-reduce at gradient-buffer sizes), the
//! sharded Adam step, schedule generation, and a short end-to-end
//! training run over the AOT artifacts.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header};

use std::sync::Arc;
use std::thread;

use frontier_llm::collectives::{Algo, Group};
use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train_with_bundle, EngineConfig};
use frontier_llm::optim::{clip_grad_norm, Adam, AdamConfig};
use frontier_llm::runtime::{Bundle, Runtime};
use frontier_llm::schedule;

fn bench_allreduce(n_ranks: usize, len: usize, algo: Algo, label: &str) {
    // spawn ranks once; each iteration is one collective round
    let group = Group::new(n_ranks);
    bench(label, 2, 20, || {
        let handles: Vec<_> = (0..n_ranks)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let mut buf = vec![1.0f32; len];
                    g.all_reduce_sum(rank, &mut buf, algo);
                    std::hint::black_box(buf[0]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

fn main() {
    header("collectives: 4-rank all-reduce of a 4M-element grad buffer");
    bench_allreduce(4, 4 << 20, Algo::Ring, "collectives::ring_4x16MB");
    bench_allreduce(4, 4 << 20, Algo::Naive, "collectives::naive_4x16MB");
    bench_allreduce(2, 1 << 20, Algo::Ring, "collectives::ring_2x4MB");

    header("optimizer: Adam step + grad clip over 4M params");
    let n = 4 << 20;
    let mut params = vec![0.1f32; n];
    let mut grads = vec![0.01f32; n];
    let mut adam = Adam::new(AdamConfig::default(), n);
    bench("optim::adam_step_4M", 2, 20, || {
        adam.step(&mut params, &grads, 1.0);
        std::hint::black_box(params[0]);
    });
    bench("optim::grad_clip_4M", 2, 50, || {
        std::hint::black_box(clip_grad_norm(&mut grads, 1e9));
    });

    header("schedule generation");
    bench("schedule::one_f1b_p64_m1600", 10, 200, || {
        std::hint::black_box(schedule::one_f1b(64, 1600));
    });
    bench("schedule::interleaved_p64_m1600_v4", 10, 200, || {
        std::hint::black_box(schedule::interleaved_1f1b(64, 1600, 4));
    });
    bench("schedule::validate_p16_m128", 10, 200, || {
        let s = schedule::one_f1b(16, 128);
        std::hint::black_box(s.validate().unwrap());
    });
    bench("schedule::validate_interleaved_p16_m128_v4", 10, 100, || {
        let s = schedule::interleaved_1f1b(16, 128, 4);
        std::hint::black_box(s.validate().unwrap());
    });

    header("end-to-end engine: builtin tiny model, 4 stages, 3 steps");
    for (label, sched) in [
        ("engine::train_builtin_1f1b_pp4", ScheduleKind::OneF1B),
        ("engine::train_builtin_interleaved_v2", ScheduleKind::Interleaved1F1B { v: 2 }),
    ] {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 1,
            schedule: sched,
            microbatches: 4,
            steps: 3,
            ..Default::default()
        };
        bench(label, 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: tensor-parallel builtin (tp2 x pp4)");
    {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s4-mb2".into(),
            dp: 1,
            tp: 2,
            schedule: ScheduleKind::OneF1B,
            microbatches: 4,
            steps: 3,
            ..Default::default()
        };
        bench("engine::train_builtin_tp2_pp4", 1, 5, || {
            std::hint::black_box(frontier_llm::coordinator::train(&cfg).unwrap());
        });
    }

    header("end-to-end engine: tiny GPT artifacts, 2-stage pipeline x dp2");
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(_) => {
            println!("(no PJRT client in this build — artifact engine bench skipped)");
            return;
        }
    };
    if root.join("tiny-s2-mb2/meta.json").exists() {
        let bundle = Arc::new(Bundle::load(&rt, root.join("tiny-s2-mb2")).unwrap());
        let cfg = EngineConfig {
            artifacts_root: root,
            bundle: "tiny-s2-mb2".into(),
            dp: 2,
            schedule: ScheduleKind::OneF1B,
            microbatches: 4,
            steps: 3,
            zero1: true,
            ..Default::default()
        };
        bench("engine::train_3steps_tiny_pp2dp2", 1, 5, || {
            std::hint::black_box(
                train_with_bundle(&cfg, rt.clone(), bundle.clone()).unwrap(),
            );
        });
    } else {
        println!("(artifacts missing — run `make artifacts` to include the engine bench)");
    }
}
