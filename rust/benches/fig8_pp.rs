//! Bench: Figure 8 — impact of pipeline depth (175B, tp8).
//!
//! 8a (Obs III.3): deeper pipeline at fixed GBS=128 loses throughput.
//! 8b (Obs III.4): scaling GBS with PP (fixed bubble ratio) holds it flat.
//! Both are also run through the discrete-event simulator to confirm the
//! measured bubble matches the analytic `(p-1)/(m+p-1)`, and an
//! interleaving sweep tracks the bubble-vs-v trend `(p-1)/(m v + p - 1)`
//! from the executed virtual-stage streams.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{lookup, ParallelConfig};
use frontier_llm::perf::{sim, PerfModel};

fn main() {
    let perf = PerfModel::default();
    let model = lookup("175b").unwrap();

    header("Fig 8a: PP sweep at fixed GBS=128");
    let mut prev = f64::INFINITY;
    for pp in [8u32, 12, 16, 24, 32] {
        let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(128);
        let b = perf.evaluate(&model, &cfg).unwrap();
        let des = sim::simulate(&perf, &model, &cfg).unwrap();
        println!(
            "PP={pp:>2}: {:>6.1} TFLOPS/GPU ({:>5.2}%)  analytic bubble {:>5.1}%  measured {:>5.1}%",
            b.tflops_per_gpu,
            b.pct_peak,
            100.0 * cfg.bubble_fraction(),
            100.0 * des.bubble_fraction
        );
        assert!(b.pct_peak < prev, "Obs III.3 must hold at PP={pp}");
        prev = b.pct_peak;
    }
    println!("[shape OK: monotone decreasing in PP at fixed GBS]");

    header("Fig 8b: PP sweep with GBS scaled (PP/M fixed)");
    let mut base = None;
    for (pp, gbs) in [(8u32, 128u32), (12, 192), (16, 256), (24, 384), (32, 512)] {
        let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(gbs);
        let b = perf.evaluate(&model, &cfg).unwrap();
        println!(
            "PP={pp:>2} GBS={gbs:>3}: {:>6.1} TFLOPS/GPU ({:>5.2}%)",
            b.tflops_per_gpu, b.pct_peak
        );
        let base = *base.get_or_insert(b.pct_peak);
        assert!(
            (b.pct_peak - base).abs() / base < 0.10,
            "Obs III.4 must hold at PP={pp}"
        );
    }
    println!("[shape OK: flat when PP/M is fixed]");

    header("Fig 8c: interleaving sweep at fixed PP=8, m=32 (bubble vs v)");
    let mut prev_bubble = f64::INFINITY;
    for v in [1u32, 2, 4, 8] {
        let cfg = ParallelConfig::default()
            .with_tp(8)
            .with_pp(8)
            .with_gbs(32)
            .with_interleave(v);
        let b = perf.evaluate(&model, &cfg).unwrap();
        let des = sim::simulate(&perf, &model, &cfg).unwrap();
        let analytic = cfg.bubble_fraction();
        println!(
            "v={v}: {:>6.1} TFLOPS/GPU ({:>5.2}%)  analytic bubble {:>5.2}%  measured {:>5.2}%",
            b.tflops_per_gpu,
            b.pct_peak,
            100.0 * analytic,
            100.0 * des.bubble_fraction
        );
        assert!(
            des.bubble_fraction < prev_bubble,
            "measured bubble must shrink with v (v={v})"
        );
        prev_bubble = des.bubble_fraction;
    }
    println!("[shape OK: measured bubble strictly shrinks with interleave depth]");

    let cfg = ParallelConfig::default().with_tp(8).with_pp(32).with_gbs(512);
    bench("fig8::des_pp32_m512", 2, 20, || {
        std::hint::black_box(sim::simulate(&perf, &model, &cfg).unwrap());
    });
    let icfg = ParallelConfig::default()
        .with_tp(8)
        .with_pp(8)
        .with_gbs(512)
        .with_interleave(4);
    bench("fig8::des_interleaved_pp8_v4_m512", 2, 20, || {
        std::hint::black_box(sim::simulate(&perf, &model, &icfg).unwrap());
    });

    write_report();
}
