//! Bench: Tables I, II and V.
//!
//! Regenerates the three static tables of the paper and times the
//! underlying calculations (model-zoo parameter counting, the Table II
//! memory accounting, and full perf-model evaluation of the Table V
//! recipes).

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{fig11_recipes, paper_zoo};
use frontier_llm::mem;
use frontier_llm::perf::PerfModel;

fn main() {
    header("Table I: model zoo");
    println!(
        "{:>6} {:>8} {:>8} {:>7} {:>13} {:>13}",
        "model", "layers", "hidden", "heads", "12Ld^2", "exact"
    );
    for m in paper_zoo() {
        println!(
            "{:>6} {:>8} {:>8} {:>7} {:>13.3e} {:>13.3e}",
            m.name, m.n_layers, m.hidden, m.n_heads,
            m.paper_params() as f64, m.total_params() as f64
        );
    }
    bench("table1::param_counting", 10, 1000, || {
        for m in paper_zoo() {
            std::hint::black_box(m.total_params());
        }
    });

    header("Table II: memory requirement");
    for (name, n, want_gb) in [
        ("22B", 22e9 as u64, 308.0),
        ("175B", 175e9 as u64, 2450.0),
        ("1T", 1_000_000_000_000u64, 14000.0),
    ] {
        let (p, g, o, t) = mem::table2_row(n);
        println!(
            "{name:>6}: params {:.0} GB, grads {:.0} GB, optim {:.0} GB, total {:.0} GB (paper {want_gb:.0} GB)",
            p as f64 / 1e9, g as f64 / 1e9, o as f64 / 1e9, t as f64 / 1e9
        );
        assert!((t as f64 / 1e9 - want_gb).abs() / want_gb < 0.01, "Table II mismatch");
    }

    header("Table V: tuned recipes through the perf model");
    let perf = PerfModel::default();
    for (r, paper_pct, paper_tf) in fig11_recipes() {
        let b = perf.evaluate(&r.model, &r.parallel).expect("recipe evaluates");
        println!(
            "{:>6}: paper {paper_pct:>6.2}% ({paper_tf:>5.1} TF)  model {:>6.2}% ({:>5.1} TF)",
            r.model.name, b.pct_peak, b.tflops_per_gpu
        );
    }
    bench("table5::recipe_evaluation", 10, 200, || {
        for (r, _, _) in fig11_recipes() {
            std::hint::black_box(perf.evaluate(&r.model, &r.parallel).unwrap());
        }
    });

    // per-GPU memory model over the recipes (the HPO hot path)
    bench("mem::per_gpu_all_recipes", 10, 1000, || {
        for (r, _, _) in fig11_recipes() {
            std::hint::black_box(mem::per_gpu(&r.model, &r.parallel));
        }
    });

    write_report();
}
