//! Bench: Figure 11 — achieved GPU throughput for 22B / 175B / 1T, plus
//! the §V.A Flash-Attention ablation.
//!
//! Shape contracts: ordering 22B > 175B > 1T; each recipe within 2 points
//! of the paper; FA ablation shows a material gain ("up to 30%").

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::fig11_recipes;
use frontier_llm::perf::PerfModel;

fn main() {
    header("Fig 11: MI250X throughput for the Table V recipes");
    let perf = PerfModel::default();

    let mut ours = Vec::new();
    for (r, paper_pct, paper_tf) in fig11_recipes() {
        let b = perf.evaluate(&r.model, &r.parallel).expect("recipe evaluates");
        println!(
            "{:>6}: paper {paper_pct:>6.2}% / {paper_tf:>5.1} TF   model {:>6.2}% / {:>5.1} TF   delta {:>+5.2}",
            r.model.name, b.pct_peak, b.tflops_per_gpu, b.pct_peak - paper_pct
        );
        assert!((b.pct_peak - paper_pct).abs() < 2.0, "{} off target", r.model.name);
        ours.push(b.pct_peak);
    }
    assert!(ours[0] > ours[1] && ours[1] > ours[2], "ordering must hold");
    println!("[shape OK: 22B > 175B > 1T, all within 2 points of paper]");

    header("§V.A ablation: Flash-Attention on/off");
    for (r, _, _) in fig11_recipes() {
        let with = perf.evaluate(&r.model, &r.parallel).unwrap().tflops_per_gpu;
        let without = perf
            .evaluate(&r.model, &r.parallel.clone().with_flash(false))
            .unwrap()
            .tflops_per_gpu;
        println!(
            "{:>6}: {with:>5.1} TF with FA2, {without:>5.1} TF without  (+{:.1}%)",
            r.model.name,
            100.0 * (with / without - 1.0)
        );
    }

    let (r, _, _) = fig11_recipes().into_iter().next_back().unwrap();
    bench("fig11::eval_1t_recipe", 10, 1000, || {
        std::hint::black_box(perf.evaluate(&r.model, &r.parallel).unwrap());
    });

    write_report();
}
