//! Bench: Figure 10 — SHAP sensitivity of the tuned hyper-parameters.
//!
//! Shape contracts: the batching/parallelism knobs (mbs/tp/pp) carry the
//! attribution mass; zero_stage and num_nodes trail (paper: "utilizing
//! ZeRO-1 has the least impact").

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::hpo::{self, shap, surrogate::Gp, SearchConfig};
use frontier_llm::perf::PerfModel;

fn main() {
    header("Fig 10: mean |SHAP| per hyper-parameter");
    let perf = PerfModel::default();
    let result = hpo::run_search(
        &perf,
        &SearchConfig { n_evals: 128, n_init: 24, n_candidates: 256, seed: 7 },
    );
    let ranking = hpo::shap_ranking(&result, 96);
    for (name, v) in &ranking {
        let bar = "#".repeat((v * 8.0) as usize);
        println!("{name:<12} {v:>7.3}  {bar}");
    }
    let names: Vec<&str> = ranking.iter().map(|(n, _)| n.as_str()).collect();
    assert!(names[..3].contains(&"p:mbs"), "mbs must rank top-3: {names:?}");
    assert!(names[3..].contains(&"p:zero_stage"), "zero_stage must trail: {names:?}");
    println!("[shape OK: mbs/tp/pp dominate, zero_stage + num_nodes trail]");

    // time the exact-SHAP computation itself
    let x: Vec<Vec<f64>> = result.evals.iter().map(|e| e.point.features().to_vec()).collect();
    let y = hpo::penalised_objectives(&result.evals);
    let gp = Gp::fit(&x[..64], &y[..64]);
    let bg: Vec<Vec<f64>> = x.iter().take(8).cloned().collect();
    bench("fig10::exact_shap_one_point", 2, 50, || {
        std::hint::black_box(shap::shapley_values_multi(&gp, &x[0], &bg));
    });
    bench("fig10::gp_fit_64pts", 2, 50, || {
        std::hint::black_box(Gp::fit(&x[..64], &y[..64]));
    });

    write_report();
}
