//! Shared micro-bench harness for the paper-figure benches.
//!
//! The build is fully offline (no criterion); this provides the same
//! essentials: warmup, repeated timed runs, mean/min/σ reporting, and a
//! `row!`-style table printer so every bench regenerates its paper
//! table/figure alongside the timing.

use std::time::Instant;

/// Timing summary of one benched closure.
#[allow(dead_code)] // each harness=false bench links this module separately
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
    pub iters: u32,
}

#[allow(dead_code)]
impl Timing {
    pub fn per_iter_display(&self) -> String {
        fmt_duration(self.mean_s)
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` timed.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    let t = Timing { mean_s: mean, min_s: min, stddev_s: var.sqrt(), iters };
    println!(
        "bench {name:<40} {:>12}/iter (min {:>12}, σ {:>10}, n={iters})",
        fmt_duration(t.mean_s),
        fmt_duration(t.min_s),
        fmt_duration(t.stddev_s)
    );
    t
}

/// Section header shared by all paper benches.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}
