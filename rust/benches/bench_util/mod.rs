//! Shared micro-bench harness for the paper-figure benches.
//!
//! The build is fully offline (no criterion); this provides the same
//! essentials: warmup, repeated timed runs, mean/min/σ reporting, and a
//! `row!`-style table printer so every bench regenerates its paper
//! table/figure alongside the timing.
//!
//! Every [`bench`] call also registers its timing; [`write_report`]
//! (called at the end of each bench main) merges the registered
//! sections into the machine-readable `BENCH_engine.json` at the repo
//! root (override the path with `BENCH_ENGINE_JSON`), preserving
//! sections written by other benches — the PR-over-PR perf trajectory
//! record.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Timing summary of one benched closure.
#[allow(dead_code)] // each harness=false bench links this module separately
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
    pub iters: u32,
}

#[allow(dead_code)]
impl Timing {
    pub fn per_iter_display(&self) -> String {
        fmt_duration(self.mean_s)
    }
}

pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Run `f` repeatedly: `warmup` discarded iterations then `iters` timed.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / samples.len() as f64;
    let t = Timing { mean_s: mean, min_s: min, stddev_s: var.sqrt(), iters };
    println!(
        "bench {name:<40} {:>12}/iter (min {:>12}, σ {:>10}, n={iters})",
        fmt_duration(t.mean_s),
        fmt_duration(t.min_s),
        fmt_duration(t.stddev_s)
    );
    registry().lock().unwrap().push((name.to_string(), t));
    t
}

/// Section header shared by all paper benches.
pub fn header(title: &str) {
    println!("\n=== {title} ===");
}

fn registry() -> &'static Mutex<Vec<(String, Timing)>> {
    static REGISTRY: OnceLock<Mutex<Vec<(String, Timing)>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn meta_registry() -> &'static Mutex<BTreeMap<String, String>> {
    static META: OnceLock<Mutex<BTreeMap<String, String>>> = OnceLock::new();
    META.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Record a run-level key/value (e.g. the precision modes a bench
/// covered) into the report's `meta` block; merged like bench sections.
#[allow(dead_code)]
pub fn record_meta(key: &str, value: &str) {
    meta_registry().lock().unwrap().insert(key.to_string(), value.to_string());
}

/// Default report path: `<repo root>/BENCH_engine.json` (the bench crate
/// lives in `rust/`), overridable with `BENCH_ENGINE_JSON`.
#[allow(dead_code)]
fn report_path() -> String {
    std::env::var("BENCH_ENGINE_JSON")
        .unwrap_or_else(|_| format!("{}/../BENCH_engine.json", env!("CARGO_MANIFEST_DIR")))
}

/// Merge this process's registered sections into `BENCH_engine.json`:
/// per-section mean/min ns-per-iter, keys sorted, sections from other
/// benches preserved.  Call once at the end of each bench `main`.
#[allow(dead_code)]
pub fn write_report() {
    use frontier_llm::util::json::{escape, Json};

    let path = report_path();
    // existing sections survive (fig benches + engine_hotpath compose
    // one file); unparseable/absent files start fresh
    let mut sections: BTreeMap<String, (f64, f64, u32)> = BTreeMap::new();
    let mut meta: BTreeMap<String, String> = BTreeMap::new();
    if let Ok(src) = std::fs::read_to_string(&path) {
        if let Ok(Json::Obj(top)) = Json::parse(&src) {
            if let Some(Json::Obj(benches)) = top.get("benches") {
                for (name, entry) in benches {
                    let mean = entry.f64_field("mean_ns").unwrap_or(0.0);
                    let min = entry.f64_field("min_ns").unwrap_or(0.0);
                    let iters = entry.u64_field("iters").unwrap_or(0) as u32;
                    sections.insert(name.clone(), (mean, min, iters));
                }
            }
            if let Some(Json::Obj(existing)) = top.get("meta") {
                for (k, v) in existing {
                    if let Some(s) = v.as_str() {
                        meta.insert(k.clone(), s.to_string());
                    }
                }
            }
        }
    }
    for (name, t) in registry().lock().unwrap().iter() {
        sections.insert(name.clone(), (t.mean_s * 1e9, t.min_s * 1e9, t.iters));
    }
    for (k, v) in meta_registry().lock().unwrap().iter() {
        meta.insert(k.clone(), v.clone());
    }
    let mut out = String::from("{\n");
    if !meta.is_empty() {
        out.push_str("  \"meta\": {\n");
        let mut first = true;
        for (k, v) in &meta {
            if !first {
                out.push_str(",\n");
            }
            first = false;
            out.push_str(&format!("    {}: {}", escape(k), escape(v)));
        }
        out.push_str("\n  },\n");
    }
    out.push_str("  \"benches\": {\n");
    let mut first = true;
    for (name, (mean_ns, min_ns, iters)) in &sections {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str(&format!(
            "    {}: {{\"mean_ns\": {mean_ns:.1}, \"min_ns\": {min_ns:.1}, \"iters\": {iters}}}",
            escape(name)
        ));
    }
    out.push_str("\n  }\n}\n");
    match std::fs::write(&path, out) {
        Ok(()) => println!("\n[bench report: {} sections -> {path}]", sections.len()),
        Err(e) => eprintln!("\n[bench report: failed to write {path}: {e}]"),
    }
}
