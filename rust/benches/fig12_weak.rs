//! Bench: Figure 12 — weak scaling (per-replica batch fixed).
//!
//! Shape contract: ~100% efficiency for both 175B (640/replica, up to
//! 1024 GPUs) and 1T (1600/replica, up to 3072 GPUs).

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{recipe_175b, recipe_1t};
use frontier_llm::metrics::weak_scaling_efficiency;
use frontier_llm::perf::PerfModel;

fn main() {
    let perf = PerfModel::default();
    for (recipe, points, label) in [
        (recipe_175b(), vec![128u32, 256, 512, 1024], "175b @ 640/replica"),
        (recipe_1t(), vec![512, 1024, 2048, 3072], "1t @ 1600/replica"),
    ] {
        header(&format!("Fig 12: weak scaling, {label}"));
        let per_replica = recipe.parallel.gpus_per_replica();
        let gbs_rep = recipe.parallel.gbs / recipe.parallel.dp;
        let mut base: Option<(u32, f64)> = None;
        let mut last_eff = 100.0;
        for gpus in points {
            let dp = gpus / per_replica;
            if dp == 0 {
                continue;
            }
            let cfg = recipe.parallel.clone().with_dp(dp).with_gbs(gbs_rep * dp);
            let sps = perf.samples_per_sec(&recipe.model, &cfg).unwrap();
            let eff = base.map(|b| weak_scaling_efficiency(b, (gpus, sps))).unwrap_or(100.0);
            if base.is_none() {
                base = Some((gpus, sps));
            }
            println!("{gpus:>5} GPUs (dp {dp:>3}): {sps:>9.2} samples/s   eff {eff:>6.2}%");
            last_eff = eff;
        }
        // paper: 100% weak scaling; the model must stay above 95%
        assert!(last_eff > 95.0, "weak scaling efficiency too low: {last_eff:.2}%");
        println!("[shape OK: ~100% weak scaling (paper: 100%)]");
    }

    let r = recipe_1t();
    let cfg = r.parallel.clone().with_dp(6).with_gbs(1600 * 6);
    bench("fig12::samples_per_sec_1t_3072gpu", 10, 1000, || {
        std::hint::black_box(perf.samples_per_sec(&r.model, &cfg).unwrap());
    });

    write_report();
}
