//! Bench: Figure 13 — strong scaling (total batch fixed).
//!
//! Shape contract: high-80s/low-90s efficiency at max scale
//! (paper: 89.93% for 175B @ 1024 GPUs / GBS 8000, 87.05% for 1T @ 3072
//! GPUs / GBS 8016), with efficiency *decreasing* in GPU count because
//! the per-replica micro-batch pool shrinks and the bubble grows.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{recipe_175b, recipe_1t};
use frontier_llm::metrics::strong_scaling_efficiency;
use frontier_llm::perf::PerfModel;

fn main() {
    let perf = PerfModel::default();
    for (recipe, gbs, points, paper_eff) in [
        (recipe_175b(), 8000u32, vec![128u32, 256, 512, 1024], 89.93),
        (recipe_1t(), 8016, vec![512, 1024, 2048, 3072], 87.05),
    ] {
        header(&format!(
            "Fig 13: strong scaling, {} @ total GBS {gbs}",
            recipe.model.name
        ));
        let per_replica = recipe.parallel.gpus_per_replica();
        let mut base: Option<(u32, f64)> = None;
        let mut effs = Vec::new();
        for gpus in points {
            let dp = gpus / per_replica;
            if dp == 0 {
                continue;
            }
            let adj = (gbs / dp) * dp;
            let cfg = recipe.parallel.clone().with_dp(dp).with_gbs(adj);
            let sps = perf.samples_per_sec(&recipe.model, &cfg).unwrap();
            let eff = base.map(|b| strong_scaling_efficiency(b, (gpus, sps))).unwrap_or(100.0);
            if base.is_none() {
                base = Some((gpus, sps));
            }
            println!("{gpus:>5} GPUs (dp {dp:>3}, gbs {adj:>5}): {sps:>9.2} samples/s   eff {eff:>6.2}%");
            effs.push(eff);
        }
        let last = *effs.last().unwrap();
        println!(
            "final efficiency {last:.2}% (paper {paper_eff}%)"
        );
        // Shape contract: efficiency decreases with GPU count and lands
        // high-80s-to-high-90s.  Our model is ~7-9 points above the paper
        // at max scale: the paper's extra losses come from network
        // instability at 1024-3072 GPUs (the very problem §V.A's AWS OFI
        // RCCL plugin mitigates) and straggler jitter across replicas —
        // effects a first-principles model cannot include without also
        // (wrongly) degrading the 100% weak-scaling result.  Documented
        // in EXPERIMENTS.md.
        assert!(effs.windows(2).all(|w| w[1] <= w[0] + 1e-9), "{effs:?}");
        assert!(last >= paper_eff - 2.0, "endpoint far below paper: {last:.2} vs {paper_eff}");
        assert!(last - paper_eff < 12.0, "endpoint too optimistic: {last:.2} vs {paper_eff}");
        println!("[shape OK: decreasing efficiency, endpoint within the documented gap]");
    }

    let r = recipe_175b();
    let cfg = r.parallel.clone().with_dp(16).with_gbs(8000);
    bench("fig13::samples_per_sec_175b_1024gpu", 10, 1000, || {
        std::hint::black_box(perf.samples_per_sec(&r.model, &cfg).unwrap());
    });

    write_report();
}
