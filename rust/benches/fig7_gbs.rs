//! Bench: Figure 7 — GPU throughput vs global batch size (22B and 1T).
//!
//! Shape contract (Obs III.2): throughput rises with GBS because more
//! micro-batches shrink the pipeline bubble.

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{lookup, ParallelConfig};
use frontier_llm::perf::PerfModel;

fn main() {
    let perf = PerfModel::default();

    for (name, tp, pp, gbs_list, zero1) in [
        ("22b", 2u32, 8u32, vec![8u32, 16, 32, 64, 128, 256], false),
        ("1t", 8, 64, vec![64, 128, 256, 512, 1024, 1600], true),
    ] {
        header(&format!("Fig 7 ({name}): throughput vs GBS, tp{tp} pp{pp}"));
        let model = lookup(name).unwrap();
        let mut prev = 0.0;
        for &gbs in &gbs_list {
            let cfg = ParallelConfig::default()
                .with_tp(tp)
                .with_pp(pp)
                .with_gbs(gbs)
                .with_zero1(zero1);
            let b = perf.evaluate(&model, &cfg).unwrap();
            let bubble = 100.0 * cfg.bubble_fraction();
            println!(
                "GBS={gbs:>4} (m={:>4}): {:>6.1} TFLOPS/GPU ({:>5.2}%)  bubble {bubble:>5.1}%",
                cfg.microbatches(),
                b.tflops_per_gpu,
                b.pct_peak
            );
            assert!(b.pct_peak > prev, "Obs III.2 must hold at {name} GBS={gbs}");
            prev = b.pct_peak;
        }
        println!("[shape OK: monotone increasing in GBS]");
    }

    let model = lookup("1t").unwrap();
    let cfg = ParallelConfig::default().with_tp(8).with_pp(64).with_gbs(1600).with_zero1(true);
    bench("fig7::eval_1t_gbs1600", 10, 500, || {
        std::hint::black_box(perf.evaluate(&model, &cfg).unwrap());
    });

    write_report();
}
