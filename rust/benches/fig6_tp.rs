//! Bench: Figure 6 — GPU throughput vs tensor-parallel size (1.4B, 8 GPUs).
//!
//! Shape contract (Obs III.1): throughput decreases monotonically with TP,
//! with the big cliff beyond TP=2 (off the 200 GB/s GCD pair).

#[path = "bench_util/mod.rs"]
mod bench_util;
use bench_util::{bench, header, write_report};

use frontier_llm::config::{lookup, ParallelConfig};
use frontier_llm::perf::{sim, PerfModel};

fn main() {
    header("Fig 6: throughput vs TP (1.4B model, 8 GPUs)");
    let perf = PerfModel::default();
    let model = lookup("1.4b").unwrap();

    let mut series = Vec::new();
    for tp in [1u32, 2, 4, 8] {
        let cfg = ParallelConfig::default()
            .with_tp(tp)
            .with_dp(8 / tp)
            .with_gbs(64)
            .with_mbs(4);
        let b = perf.evaluate(&model, &cfg).unwrap();
        println!("TP={tp}: {:>6.1} TFLOPS/GPU ({:>5.2}% of peak)", b.tflops_per_gpu, b.pct_peak);
        series.push((tp, b.pct_peak));
    }
    for w in series.windows(2) {
        assert!(w[1].1 < w[0].1, "Obs III.1 must hold: {series:?}");
    }
    println!("[shape OK: monotone decreasing in TP]");

    let cfg = ParallelConfig::default().with_tp(8).with_gbs(64).with_mbs(4);
    bench("fig6::analytic_eval", 10, 2000, || {
        std::hint::black_box(perf.evaluate(&model, &cfg).unwrap());
    });
    bench("fig6::des_eval", 2, 50, || {
        std::hint::black_box(sim::simulate(&perf, &model, &cfg).unwrap());
    });

    write_report();
}
