//! Cross-layer integration tests: python-emitted artifacts vs the rust
//! model zoo, runtime execution, and perf-model consistency.
//!
//! These need `make artifacts` to have run (the Makefile `test` target
//! guarantees it).

use std::path::{Path, PathBuf};

use frontier_llm::config::{self, ParallelConfig};
use frontier_llm::perf::{sim, PerfModel};
use frontier_llm::runtime::{Bundle, BundleMeta, Runtime};

/// Artifact root, or `None` (skip) when `make artifacts` has not run.
fn artifacts_root() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("tiny-s2-mb2/meta.json").exists() {
        Some(root)
    } else {
        eprintln!("skipping: artifacts missing — run `make artifacts` first");
        None
    }
}

fn load_meta(bundle: &str) -> BundleMeta {
    let path = artifacts_root().unwrap().join(bundle).join("meta.json");
    BundleMeta::from_json(&std::fs::read_to_string(&path).unwrap()).unwrap()
}

#[test]
fn meta_model_matches_rust_zoo() {
    if artifacts_root().is_none() {
        return;
    }
    // the python configs.py and rust config::model must agree exactly
    for bundle in ["tiny-s2-mb2", "mini-s2-mb2", "mini-s4-mb1", "gpt-10m-s2-mb1"] {
        let meta = load_meta(bundle);
        let spec = config::lookup(&meta.model.name)
            .unwrap_or_else(|| panic!("{} not in rust zoo", meta.model.name));
        assert_eq!(spec.n_layers, meta.model.n_layers, "{bundle}");
        assert_eq!(spec.hidden, meta.model.hidden, "{bundle}");
        assert_eq!(spec.n_heads, meta.model.n_heads, "{bundle}");
        assert_eq!(spec.vocab, meta.model.vocab, "{bundle}");
        assert_eq!(spec.seq, meta.model.seq, "{bundle}");
        assert_eq!(spec.total_params(), meta.model.total_params, "{bundle}");
    }
}

#[test]
fn meta_stage_params_sum_to_total() {
    if artifacts_root().is_none() {
        return;
    }
    for bundle in ["tiny-s2-mb2", "mini-s4-mb1"] {
        let meta = load_meta(bundle);
        let sum: u64 = meta.stages.iter().map(|s| s.param_count).sum();
        assert_eq!(sum, meta.model.total_params, "{bundle}");
        // spans cover all layers contiguously
        assert_eq!(meta.stages[0].layer_start, 0);
        assert_eq!(meta.stages.last().unwrap().layer_end, meta.model.n_layers);
        for w in meta.stages.windows(2) {
            assert_eq!(w[0].layer_end, w[1].layer_start);
        }
        assert!(meta.stages[0].has_embed);
        assert!(meta.stages.last().unwrap().has_head);
    }
}

#[test]
fn meta_flops_consistent_with_rust_model() {
    if artifacts_root().is_none() {
        return;
    }
    let meta = load_meta("tiny-s2-mb2");
    let spec = config::lookup("tiny").unwrap();
    let expect = spec.flops_per_token() * meta.tokens_per_microbatch as f64;
    let rel = (meta.flops_per_microbatch - expect).abs() / expect;
    assert!(rel < 0.05, "python {} vs rust {expect}", meta.flops_per_microbatch);
}

#[test]
fn runtime_executes_stage_forward() {
    let Some(root) = artifacts_root() else { return };
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client in this build");
        return;
    };
    let bundle = Bundle::load(&rt, root.join("tiny-s2-mb2")).unwrap();
    let meta = &bundle.meta;
    let dims = bundle.dims();
    let (b, s, d) = (dims.b, dims.s, dims.d);

    // init stage 0, run its forward on a token batch through the typed
    // stage contract (same entry points the workers drive)
    let params = bundle.stages[0].init_params(1).unwrap();
    assert_eq!(params.len() as u64, bundle.stages[0].meta.param_count);
    // init must be non-degenerate
    let nonzero = params.iter().filter(|&&p| p != 0.0).count();
    assert!(nonzero > params.len() / 4);

    let tokens: Vec<i32> = (0..b * s).map(|i| (i % meta.model.vocab as usize) as i32).collect();
    let handle = bundle.stages[0].prepare_params(&rt, &params).unwrap();
    let comm = frontier_llm::collectives::TpComm::solo();
    let h = bundle.stages[0].fwd_first(&rt, &handle, &comm, &tokens, dims).unwrap();
    assert_eq!(h.len(), b * s * d);
    assert!(h.iter().all(|x| x.is_finite()));
}

#[test]
fn runtime_rejects_missing_bundle() {
    let Ok(rt) = Runtime::cpu() else {
        eprintln!("skipping: no PJRT client in this build");
        return;
    };
    assert!(Bundle::load(&rt, Path::new("artifacts/does-not-exist")).is_err());
}

#[test]
fn perf_model_covers_whole_exec_zoo() {
    // every executable model evaluates cleanly at a trivial config
    let perf = PerfModel::default();
    for spec in config::exec_zoo() {
        let cfg = ParallelConfig::default().with_gbs(4).with_mbs(1);
        let b = perf.evaluate(&spec, &cfg).unwrap();
        assert!(b.t_step > 0.0 && b.pct_peak > 0.0, "{}", spec.name);
    }
}

#[test]
fn des_and_analytic_agree_across_grid() {
    // systematic cross-validation of the two evaluators
    let perf = PerfModel::default();
    let m = config::lookup("22b").unwrap();
    for pp in [1u32, 2, 4, 8] {
        for gbs in [16u32, 64] {
            let cfg = ParallelConfig::default().with_tp(2).with_pp(pp).with_gbs(gbs);
            // shallow pipelines legitimately OOM at 22B (the memory wall
            // §II.A) — the grid only compares feasible points
            let Ok(ana) = perf.evaluate(&m, &cfg) else { continue };
            let des = sim::simulate(&perf, &m, &cfg).unwrap();
            let rel = (des.pct_peak - ana.pct_peak).abs() / ana.pct_peak;
            assert!(
                rel < 0.2,
                "pp={pp} gbs={gbs}: des {:.2} ana {:.2}",
                des.pct_peak,
                ana.pct_peak
            );
        }
    }
}

#[test]
fn observation_v_a_saturation_recipes() {
    // §V.A: both Table V recipes satisfy m >= p and TP <= 8 within a node
    for (r, _, _) in config::fig11_recipes() {
        assert!(r.parallel.microbatches() >= r.parallel.pp);
        assert!(r.parallel.tp <= 8);
    }
}
