//! Engine-level tests of the mixed-precision subsystem: bf16 storage /
//! compute with fp32 master weights, dynamic loss scaling, and the
//! half-width wire contracts.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **fp32 bitwise-unchanged** — the legacy tests in `tests/engine.rs` /
//!   `tests/overlap.rs` run the default `Dtype::F32` path verbatim; here
//!   we additionally pin that an explicit (power-of-two) loss scale is
//!   numerically invisible, so the scaling machinery cannot perturb
//!   anything.
//! * **bf16 tracks fp32** — 20-step loss trajectories at
//!   tp ∈ {1, 2} × pp ∈ {1, 2}, dp = 2 with ZeRO-1, within a stated
//!   relative tolerance.
//! * **half-width wire, pinned EXACTLY** — engine-measured TP all-reduce
//!   payload bytes and DP grad-bucket payload bytes at bf16 equal the
//!   dtype-aware `perf` contract terms exactly, and are exactly half the
//!   fp32 measurement; ZeRO-1's wire accounting splits into
//!   reduce-scatter + all-gather halves at dp ∈ {2, 4}.
//! * **loss scaler** — forced overflow skips the step and halves the
//!   scale; a clean run at a growth interval doubles it on schedule; the
//!   whole scaler state survives checkpoint resume.

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::perf::{
    builtin_tp_ar_bytes_per_microbatch, builtin_tp_grad_sync_bytes_per_step,
    dp_grad_payload_bytes, zero1_allgather_payload_bytes,
};
use frontier_llm::precision::Dtype;
use frontier_llm::runtime::BuiltinSpec;
use frontier_llm::zero::ShardingStage;

/// Stated bf16-vs-fp32 trajectory tolerance (relative): bf16 keeps f32's
/// exponent range but only ~2.4 decimal digits, and the drift compounds
/// over 20 optimizer steps.
const BF16_TRAJ_TOL: f32 = 0.08;

#[allow(clippy::too_many_arguments)]
fn cfg(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    zero1: bool,
    sched: ScheduleKind,
    precision: Dtype,
) -> EngineConfig {
    EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        schedule: sched,
        microbatches: m,
        steps,
        zero_stage: if zero1 { ShardingStage::OptimizerStates } else { ShardingStage::Ddp },
        precision,
        seed: 42,
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    zero1: bool,
    sched: ScheduleKind,
    precision: Dtype,
) -> TrainReport {
    train(&cfg(bundle, tp, dp, m, steps, zero1, sched, precision))
        .expect("training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

// =========================================================================
// trajectory: bf16 tracks fp32 across the parallelism grid
// =========================================================================

#[test]
fn bf16_tracks_fp32_trajectory_20_steps_tp_pp_grid() {
    // tp ∈ {1, 2} × pp ∈ {1, 2} over the same 2-stage model (pp = 1 via
    // v = 2 chunking), dp = 2 with ZeRO-1 — the acceptance grid
    let grid: &[(usize, ScheduleKind, &str)] = &[
        (1, ScheduleKind::OneF1B, "tp1 pp2"),
        (2, ScheduleKind::OneF1B, "tp2 pp2"),
        (1, ScheduleKind::Interleaved1F1B { v: 2 }, "tp1 pp1(v2)"),
        (2, ScheduleKind::Interleaved1F1B { v: 2 }, "tp2 pp1(v2)"),
    ];
    for &(tp, sched, label) in grid {
        let fp32 = run("builtin:tiny-s2-mb2", tp, 2, 2, 20, true, sched, Dtype::F32);
        let bf16 = run("builtin:tiny-s2-mb2", tp, 2, 2, 20, true, sched, Dtype::Bf16);
        assert_eq!(fp32.precision, Dtype::F32);
        assert_eq!(bf16.precision, Dtype::Bf16);
        assert!(bf16.logs.iter().all(|l| l.loss.is_finite()), "{label}: bf16 loss finite");
        assert_eq!(bf16.steps_skipped, 0, "{label}: no overflow at scale 1");
        assert_close(&losses(&fp32), &losses(&bf16), BF16_TRAJ_TOL, label);
    }
}

#[test]
fn bf16_engine_is_deterministic() {
    let a = run("builtin:tiny-s2-mb2", 2, 2, 2, 6, true, ScheduleKind::OneF1B, Dtype::Bf16);
    let b = run("builtin:tiny-s2-mb2", 2, 2, 2, 6, true, ScheduleKind::OneF1B, Dtype::Bf16);
    assert_eq!(losses(&a), losses(&b), "bf16 engine must be deterministic");
}

#[test]
fn bf16_overlapped_sync_is_bit_identical_to_sequential() {
    // the PR-3 overlap invariant survives the packed-u16 wire: bucketed
    // bf16 deposits still reduce in rank order
    let mk = |overlap: bool| {
        let mut c = cfg(
            "builtin:tiny-s2-mb2",
            1,
            2,
            2,
            10,
            false,
            ScheduleKind::OneF1B,
            Dtype::Bf16,
        );
        c.overlap_grad_sync = overlap;
        c.grad_bucket_floats = 64;
        train(&c).expect("training must succeed")
    };
    assert_eq!(losses(&mk(true)), losses(&mk(false)), "bf16 overlap changed the trajectory");
}

// =========================================================================
// loss scaling: exactness, growth, forced overflow, resume
// =========================================================================

#[test]
fn power_of_two_loss_scale_is_numerically_invisible() {
    // scaling by 2^k is exact in both fp32 and bf16 (absent overflow), so
    // an explicit scale must not move the trajectory by a single bit —
    // including on the fp32 path, where this doubles as the proof that
    // the scaling machinery leaves the legacy numerics alone
    for precision in [Dtype::F32, Dtype::Bf16] {
        let plain = run("builtin:tiny-s2-mb2", 1, 2, 2, 8, true, ScheduleKind::OneF1B, precision);
        let mut c = cfg("builtin:tiny-s2-mb2", 1, 2, 2, 8, true, ScheduleKind::OneF1B, precision);
        c.loss_scale_init = 256.0;
        let scaled = train(&c).unwrap();
        assert_eq!(
            losses(&plain),
            losses(&scaled),
            "{}: a 2^8 loss scale must be bitwise-invisible",
            precision.name()
        );
        assert_eq!(scaled.final_loss_scale, 256.0);
        assert_eq!(scaled.steps_skipped, 0);
    }
}

#[test]
fn loss_scale_growth_doubles_on_schedule() {
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 1, 2, 10, false, ScheduleKind::OneF1B, Dtype::Bf16);
    c.loss_scale_growth_interval = 3;
    let r = train(&c).unwrap();
    // 10 clean steps at interval 3: doublings after steps 3, 6, 9
    assert_eq!(r.final_loss_scale, 8.0);
    assert_eq!(r.steps_skipped, 0);
    // growth is trajectory-neutral (powers of two)
    let plain = run("builtin:tiny-s2-mb2", 1, 1, 2, 10, false, ScheduleKind::OneF1B, Dtype::Bf16);
    assert_eq!(losses(&r), losses(&plain));
    // the per-step log records the scale schedule
    assert_eq!(r.logs[2].loss_scale, 2.0, "first doubling lands after step 3");
    assert!(r.logs.iter().all(|l| !l.skipped));
}

#[test]
fn forced_overflow_skips_steps_and_halves_the_scale() {
    // force real overflow through the engine: one healthy step at an
    // absurd LR blows the parameters up to ~1e25, so every later backward
    // produces non-finite logits/gradients — the scaler must then skip
    // the optimizer step (params frozen, Adam untouched) and halve the
    // scale, every step, deterministically
    let mut c = cfg("builtin:tiny-s1-mb2", 1, 1, 2, 6, false, ScheduleKind::OneF1B, Dtype::Bf16);
    c.adam.lr = 1e25;
    c.loss_scale_init = 65536.0;
    let r = train(&c).unwrap();
    assert_eq!(r.steps_skipped, 5, "steps 1..5 must all overflow");
    assert_eq!(r.final_loss_scale, 65536.0 / 32.0);
    assert!(!r.logs[0].skipped, "step 0 is healthy");
    assert!(r.logs[1..].iter().all(|l| l.skipped));
    assert!(r.logs[1..].iter().all(|l| l.grad_norm.is_infinite()));
}

#[test]
fn bf16_checkpoint_resume_restores_masters_and_scaler() {
    // 6 straight steps == 3 + checkpoint + 3, under bf16 + ZeRO-1 with a
    // growth interval that crosses the checkpoint boundary — so the test
    // fails unless BOTH the fp32 masters and the scaler state round-trip
    let dir = std::env::temp_dir().join(format!("fllm-bf16-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = |steps: u32, resume: bool| {
        let mut c =
            cfg("builtin:tiny-s2-mb2", 1, 2, 2, steps, true, ScheduleKind::OneF1B, Dtype::Bf16);
        c.loss_scale_growth_interval = 2;
        c.checkpoint_dir = Some(dir.clone());
        c.resume = resume;
        c
    };
    let mut straight_cfg = mk(6, false);
    straight_cfg.checkpoint_dir = None;
    let straight = train(&straight_cfg).unwrap();

    let first = train(&mk(3, false)).unwrap();
    let second = train(&mk(3, true)).unwrap();
    assert_eq!(second.logs[0].step, 3);
    let mut combined = losses(&first);
    combined.extend(losses(&second));
    assert_close(&losses(&straight), &combined, 1e-5, "bf16 resume vs straight");
    // 6 clean steps at interval 2 -> scale 2^3, resumed or not
    assert_eq!(straight.final_loss_scale, 8.0);
    assert_eq!(second.final_loss_scale, 8.0);

    // resuming the bf16 checkpoint at fp32 must be rejected (different
    // parameter grid + optimizer-state layout)
    let mut wrong = mk(3, true);
    wrong.precision = Dtype::F32;
    assert!(train(&wrong).is_err(), "precision mismatch must be rejected");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bf16_requires_builtin_bundle() {
    let c = cfg("tiny-s2-mb2", 1, 1, 2, 2, false, ScheduleKind::OneF1B, Dtype::Bf16);
    let err = train(&c).unwrap_err().to_string();
    assert!(err.contains("builtin"), "{err}");
}

// =========================================================================
// half-width wire contracts, pinned EXACTLY against perf's dtype-aware
// comm terms (the PR-2 treatment, applied to bf16)
// =========================================================================

#[test]
fn bf16_tp_ar_bytes_match_dtype_aware_term_and_halve_fp32() {
    let (tokens, hidden) = (2 * 8, 16u64); // tiny: mbs×seq, d
    let (m, steps, k) = (2u32, 3u32, 2u64);
    for tp in [2usize, 4] {
        let fp32 = run("builtin:tiny-s2-mb2", tp, 1, m, steps, false, ScheduleKind::OneF1B, Dtype::F32);
        let bf16 = run("builtin:tiny-s2-mb2", tp, 1, m, steps, false, ScheduleKind::OneF1B, Dtype::Bf16);
        let want = |wire: u64| {
            steps as u64
                * (m as u64 * builtin_tp_ar_bytes_per_microbatch(k, tokens, hidden, wire)
                    + builtin_tp_grad_sync_bytes_per_step(k, hidden, wire))
        };
        assert_eq!(fp32.tp_ar_bytes, want(4), "tp={tp}: fp32 pin");
        assert_eq!(bf16.tp_ar_bytes, want(2), "tp={tp}: bf16 pin");
        assert_eq!(2 * bf16.tp_ar_bytes, fp32.tp_ar_bytes, "tp={tp}: exactly half");
        assert_eq!(bf16.tp_ar_rounds, fp32.tp_ar_rounds, "same collective count");
    }
}

#[test]
fn dp_bucket_payload_matches_dtype_aware_term_and_halves() {
    let spec = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
    let total = spec.total_params() as u64;
    let steps = 5u32;
    for dp in [2usize, 4] {
        let fp32 = run("builtin:tiny-s2-mb2", 1, dp, 2, steps, false, ScheduleKind::OneF1B, Dtype::F32);
        let bf16 = run("builtin:tiny-s2-mb2", 1, dp, 2, steps, false, ScheduleKind::OneF1B, Dtype::Bf16);
        // every parameter's gradient crosses the DP group once per step,
        // regardless of dp and bucket count
        assert_eq!(
            fp32.dp_bucket_payload_bytes,
            steps as u64 * dp_grad_payload_bytes(total, 4),
            "dp={dp}: fp32 bucket payload"
        );
        assert_eq!(
            bf16.dp_bucket_payload_bytes,
            steps as u64 * dp_grad_payload_bytes(total, 2),
            "dp={dp}: bf16 bucket payload"
        );
        assert_eq!(2 * bf16.dp_bucket_payload_bytes, fp32.dp_bucket_payload_bytes);
        // plain DDP gathers no parameters
        assert_eq!(fp32.dp_param_ag_bytes, 0);
        assert_eq!(bf16.dp_param_ag_bytes, 0);
    }
}

#[test]
fn zero1_wire_accounts_as_reduce_scatter_plus_all_gather() {
    // the ZeRO-1 RS+AG wire split (closing the PR-3 ROADMAP leftover):
    // grad reduction payload == parameter all-gather payload == params ×
    // dtype width per step, at dp ∈ {2, 4} and both precisions
    let spec = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
    let total = spec.total_params() as u64;
    let steps = 4u32;
    for dp in [2usize, 4] {
        for (precision, width) in [(Dtype::F32, 4u64), (Dtype::Bf16, 2u64)] {
            let r = run("builtin:tiny-s2-mb2", 1, dp, 2, steps, true, ScheduleKind::OneF1B, precision);
            assert_eq!(
                r.dp_bucket_payload_bytes,
                steps as u64 * dp_grad_payload_bytes(total, width),
                "dp={dp} {}: reduce half",
                precision.name()
            );
            assert_eq!(
                r.dp_param_ag_bytes,
                steps as u64 * zero1_allgather_payload_bytes(total, width),
                "dp={dp} {}: all-gather half",
                precision.name()
            );
        }
    }
}

#[test]
fn bf16_zero1_matches_bf16_ddp_through_the_engine() {
    let ddp = run("builtin:tiny-s2-mb2", 1, 2, 2, 10, false, ScheduleKind::OneF1B, Dtype::Bf16);
    let z1 = run("builtin:tiny-s2-mb2", 1, 2, 2, 10, true, ScheduleKind::OneF1B, Dtype::Bf16);
    assert_close(&losses(&ddp), &losses(&z1), 5e-3, "bf16 zero1 vs ddp");
}

#[test]
fn bf16_loss_descends() {
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 1, 4, 8, false, ScheduleKind::OneF1B, Dtype::Bf16);
    c.adam.lr = 2e-2;
    let r = train(&c).unwrap();
    assert!(
        r.final_loss() < r.initial_loss(),
        "bf16 training must learn: {:?}",
        losses(&r)
    );
}
