//! Observability contract tests (PR 10).
//!
//! The tracing subsystem is **observational only** — the locks:
//!
//! * **Tracing on ≡ off, bitwise** — a 20-step dp2 × tp2, ZeRO-3, bf16
//!   run (and a dp2 × ep2 MoE run) with `--trace-out`/`--metrics-jsonl`
//!   armed walks the untraced loss/grad-norm/loss-scale trajectory bit
//!   for bit, and every pinned payload counter is equal.  Spans never
//!   touch numerics and never add collectives.
//! * **Chrome trace structural validity** — the merged export parses as
//!   JSON, `B`/`E` duration events balance per `(pid, tid)` lane with
//!   non-decreasing timestamps in emission order, and the `pid` set is
//!   exactly the world's rank set.
//! * **Span-accounting completeness** — per step and rank,
//!   Σ category self time + idle closes against the step wall time
//!   within 1% (`max_busy_over_wall <= 1.01`, Σcat + idle ≈ wall).
//! * **JSONL stream** — one line per logged step; the per-step counter
//!   deltas telescope to exactly the `TrainReport` totals; per-line
//!   scalars round-trip the `StepLog` values.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::precision::Dtype;
use frontier_llm::util::json::Json;
use frontier_llm::zero::ShardingStage;

const DENSE: &str = "builtin:tiny-s2-mb2";
const MOE4: &str = "builtin:tiny-moe4k2-s2-mb2";

fn cfg(
    bundle: &str,
    tp: usize,
    dp: usize,
    ep: usize,
    stage: ShardingStage,
    precision: Dtype,
) -> EngineConfig {
    EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        ep,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 20,
        zero_stage: stage,
        precision,
        // small buckets so the overlapped DP sync spans several rounds
        grad_bucket_floats: 128,
        seed: 42,
        ..Default::default()
    }
}

/// Fresh per-test output dir under the system temp root.
fn out_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fllm-trace-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("temp dir");
    d
}

fn loss_bits(r: &TrainReport) -> Vec<u32> {
    r.logs.iter().map(|l| l.loss.to_bits()).collect()
}

fn grad_norm_bits(r: &TrainReport) -> Vec<u32> {
    r.logs.iter().map(|l| l.grad_norm.to_bits()).collect()
}

fn scale_bits(r: &TrainReport) -> Vec<u32> {
    r.logs.iter().map(|l| l.loss_scale.to_bits()).collect()
}

/// Every *pinned* counter: payload/round/residency counters must be
/// unaffected by tracing (the `*_ns` timing counters may drift — they
/// measure wall time, which tracing legitimately perturbs within the
/// overhead budget).
fn pinned_counters(r: &TrainReport) -> Vec<u64> {
    vec![
        r.comm_bytes,
        r.tp_ar_bytes,
        r.tp_ar_rounds,
        r.dp_bucket_rounds,
        r.dp_bucket_payload_bytes,
        r.dp_param_ag_bytes,
        r.pp_p2p_payload_bytes,
        r.dp_bucket_intra_bytes,
        r.dp_bucket_inter_bytes,
        r.dp_param_ag_intra_bytes,
        r.dp_param_ag_inter_bytes,
        r.pp_p2p_intra_bytes,
        r.pp_p2p_inter_bytes,
        r.moe_a2a_rounds,
        r.moe_a2a_payload_bytes,
        r.moe_a2a_intra_bytes,
        r.moe_a2a_inter_bytes,
        r.moe_dropped_tokens,
        r.zero3_peak_gathered_floats,
    ]
}

/// Run `base` untraced and traced (both exports armed), assert the
/// observational-invisibility contract, and hand back the traced report
/// plus the export paths for structural checks.
fn run_traced_vs_untraced(base: EngineConfig, tag: &str) -> (TrainReport, PathBuf, PathBuf) {
    let off = train(&base).expect("untraced run");

    let dir = out_dir(tag);
    let trace_path = dir.join("trace.json");
    let jsonl_path = dir.join("metrics.jsonl");
    let mut traced_cfg = base;
    traced_cfg.trace_out = Some(trace_path.clone());
    traced_cfg.metrics_jsonl = Some(jsonl_path.clone());
    let on = train(&traced_cfg).expect("traced run");

    assert_eq!(loss_bits(&off), loss_bits(&on), "{tag}: losses must be bitwise");
    assert_eq!(
        grad_norm_bits(&off),
        grad_norm_bits(&on),
        "{tag}: grad norms must be bitwise"
    );
    assert_eq!(scale_bits(&off), scale_bits(&on), "{tag}: loss scales must be bitwise");
    assert_eq!(
        pinned_counters(&off),
        pinned_counters(&on),
        "{tag}: pinned counters must be identical"
    );
    assert!(off.trace_summary.is_none(), "{tag}: untraced run must record nothing");
    let s = on.trace_summary.as_ref().expect("traced run records a summary");
    assert_eq!(s.ranks, on.world_size, "{tag}: every rank flushes a timeline");
    assert_eq!(s.steps, 20, "{tag}: every step is marked");
    (on, trace_path, jsonl_path)
}

/// Span-accounting completeness: Σ category self time + idle closes
/// against wall within 1%, and no rank's busy time overruns its wall.
fn assert_accounting_closes(r: &TrainReport, tag: &str) {
    let s = r.trace_summary.as_ref().unwrap();
    assert!(s.wall_s > 0.0, "{tag}: wall must be positive");
    let cat_total: f64 = s.cat_s.iter().sum();
    let closed = cat_total + s.idle_s;
    let err = (closed - s.wall_s).abs() / s.wall_s;
    assert!(
        err < 0.01,
        "{tag}: category+idle must close against wall within 1%: \
         cats {cat_total:.6}s + idle {:.6}s vs wall {:.6}s (err {err:.4})",
        s.idle_s,
        s.wall_s
    );
    assert!(
        s.max_busy_over_wall <= 1.01,
        "{tag}: busy time must not overrun step wall by >1% (got {:.4})",
        s.max_busy_over_wall
    );
}

/// Structural validation of the Chrome Trace Event Format export.
fn assert_chrome_trace_valid(path: &PathBuf, world: usize, tag: &str) {
    let text = std::fs::read_to_string(path).expect("trace file");
    let root = Json::parse(&text).expect("trace must be valid JSON");
    let events = root
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(!events.is_empty(), "{tag}: trace must contain events");

    let mut pids: BTreeSet<u64> = BTreeSet::new();
    // per-(pid, tid) lane: open-span depth and last-seen timestamp
    let mut depth: BTreeMap<(u64, u64), i64> = BTreeMap::new();
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut cats: BTreeSet<String> = BTreeSet::new();
    for e in events {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("every event has ph");
        if ph != "B" && ph != "E" {
            continue; // metadata (M) and instants (i) don't nest
        }
        let pid = e.get("pid").and_then(|p| p.as_u64()).expect("pid");
        let tid = e.get("tid").and_then(|t| t.as_u64()).expect("tid");
        let ts = e.get("ts").and_then(|t| t.as_f64()).expect("ts");
        pids.insert(pid);
        let lane = (pid, tid);
        let last = last_ts.entry(lane).or_insert(f64::NEG_INFINITY);
        assert!(
            ts >= *last,
            "{tag}: pid {pid} tid {tid}: timestamps must be non-decreasing \
             ({ts} after {last})"
        );
        *last = ts;
        let d = depth.entry(lane).or_insert(0);
        *d += if ph == "B" { 1 } else { -1 };
        assert!(*d >= 0, "{tag}: pid {pid} tid {tid}: E without matching B");
        if ph == "B" {
            if let Some(c) = e.get("cat").and_then(|c| c.as_str()) {
                cats.insert(c.to_string());
            }
        }
    }
    for (lane, d) in &depth {
        assert_eq!(*d, 0, "{tag}: lane {lane:?} must close every B with an E");
    }
    let expect: BTreeSet<u64> = (0..world as u64).collect();
    assert_eq!(pids, expect, "{tag}: one pid per worker world rank");
    for want in ["compute", "dp_sync", "optimizer"] {
        assert!(cats.contains(want), "{tag}: category {want:?} must appear, got {cats:?}");
    }
}

/// JSONL stream: one line per logged step, scalars round-trip, and the
/// counter deltas telescope to exactly the TrainReport totals.
fn assert_jsonl_consistent(path: &PathBuf, r: &TrainReport, tag: &str) {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let lines: Vec<&str> = text.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(lines.len(), r.logs.len(), "{tag}: one JSONL line per logged step");

    let mut sums: BTreeMap<String, u64> = BTreeMap::new();
    let mut peak = 0u64;
    for (line, log) in lines.iter().zip(&r.logs) {
        let v = Json::parse(line).expect("each JSONL line is one JSON object");
        assert_eq!(
            v.get("step").and_then(|s| s.as_u64()),
            Some(log.step as u64),
            "{tag}: step ids line up"
        );
        // f32 -> f64 is exact and the writer prints shortest-roundtrip
        // f64, so finite scalars compare exactly (non-finite -> null)
        if log.loss.is_finite() {
            assert_eq!(
                v.get("loss").and_then(|l| l.as_f64()),
                Some(log.loss as f64),
                "{tag}: loss round-trips"
            );
        }
        assert_eq!(
            v.get("skipped").and_then(|s| s.as_bool()),
            Some(log.skipped),
            "{tag}: skip flag round-trips"
        );
        let trace = v.get("trace").expect("per-step trace block");
        assert!(
            trace.get("cat_ms").and_then(|c| c.get("compute")).is_some(),
            "{tag}: cat_ms carries the compute column"
        );
        let counters = v.get("counters").expect("per-step counter deltas");
        if let Json::Obj(map) = counters {
            for (k, val) in map {
                let n = val.as_u64().expect("counter values are u64");
                if k.as_str() == "zero3_peak_gathered_floats" {
                    peak = peak.max(n); // absolute high-water mark
                } else {
                    *sums.entry(k.clone()).or_insert(0) += n;
                }
            }
        } else {
            panic!("{tag}: counters must be an object");
        }
    }
    // telescoped deltas == TrainReport totals, exactly
    let total = |k: &str| sums.get(k).copied().unwrap_or(0);
    assert_eq!(total("comm_bytes"), r.comm_bytes, "{tag}: comm_bytes telescopes");
    assert_eq!(total("tp_ar_bytes"), r.tp_ar_bytes, "{tag}: tp_ar_bytes telescopes");
    assert_eq!(total("tp_ar_rounds"), r.tp_ar_rounds, "{tag}: tp_ar_rounds telescopes");
    assert_eq!(
        total("dp_bucket_payload_bytes"),
        r.dp_bucket_payload_bytes,
        "{tag}: dp bucket payload telescopes"
    );
    assert_eq!(
        total("dp_bucket_rounds"),
        r.dp_bucket_rounds,
        "{tag}: dp bucket rounds telescope"
    );
    assert_eq!(
        total("dp_param_ag_bytes"),
        r.dp_param_ag_bytes,
        "{tag}: param all-gather bytes telescope"
    );
    assert_eq!(
        total("moe_a2a_payload_bytes"),
        r.moe_a2a_payload_bytes,
        "{tag}: moe a2a payload telescopes"
    );
    assert_eq!(
        total("moe_dropped_tokens"),
        r.moe_dropped_tokens,
        "{tag}: moe drop counter telescopes"
    );
    assert_eq!(
        peak, r.zero3_peak_gathered_floats,
        "{tag}: zero3 peak is the max over lines"
    );
}

// =========================================================================
// tracing on ≡ off, bitwise — dense dp2 × tp2, ZeRO-3, bf16
// =========================================================================

#[test]
fn tracing_is_observationally_invisible_dense_zero3_bf16() {
    let (on, trace_path, jsonl_path) = run_traced_vs_untraced(
        cfg(DENSE, 2, 2, 1, ShardingStage::Parameters, Dtype::Bf16),
        "dense",
    );
    assert_accounting_closes(&on, "dense");
    assert_chrome_trace_valid(&trace_path, on.world_size, "dense");
    assert_jsonl_consistent(&jsonl_path, &on, "dense");
    // zero-3 must surface gather spans, tp2 the all-reduce spans
    let s = on.trace_summary.as_ref().unwrap();
    use frontier_llm::trace::Category;
    assert!(s.seconds(Category::ZeroGather) > 0.0, "zero-3 records gather waits");
    assert!(s.seconds(Category::TpComm) > 0.0, "tp2 records all-reduce spans");
    assert!(s.seconds(Category::Compute) > 0.0, "compute dominates somewhere");
    std::fs::remove_dir_all(trace_path.parent().unwrap()).ok();
}

// =========================================================================
// tracing on ≡ off, bitwise — MoE dp2 × ep2 over the a2a wire
// =========================================================================

#[test]
fn tracing_is_observationally_invisible_moe_ep2() {
    let (on, trace_path, jsonl_path) = run_traced_vs_untraced(
        cfg(MOE4, 1, 2, 2, ShardingStage::OptimizerStates, Dtype::F32),
        "moe",
    );
    assert!(on.moe_a2a_rounds > 0, "ep2 must route tokens over the wire");
    assert_accounting_closes(&on, "moe");
    assert_chrome_trace_valid(&trace_path, on.world_size, "moe");
    assert_jsonl_consistent(&jsonl_path, &on, "moe");
    let s = on.trace_summary.as_ref().unwrap();
    assert!(
        s.seconds(frontier_llm::trace::Category::MoeA2a) > 0.0,
        "a2a waits must be spanned"
    );
    std::fs::remove_dir_all(trace_path.parent().unwrap()).ok();
}

// =========================================================================
// trace-derived overlap agrees with the engine's timer classification
// =========================================================================

#[test]
fn trace_dp_overlap_matches_engine_classification() {
    // overlapped run: the launch spans are tagged hidden, so the trace's
    // dp_overlap and the engine's hidden/exposed-timer fraction measure
    // the same quantity from independent instrumentation
    let mut c = cfg(DENSE, 1, 2, 1, ShardingStage::OptimizerStates, Dtype::F32);
    let dir = out_dir("overlap");
    c.trace_out = Some(dir.join("trace.json"));
    let on = train(&c).expect("traced run");
    let s = on.trace_summary.as_ref().unwrap();
    let engine = on.dp_overlap_fraction();
    assert!(
        (s.dp_overlap - engine).abs() < 0.35,
        "trace-classified dp overlap ({:.3}) must track the engine's ({engine:.3})",
        s.dp_overlap
    );

    // sequential sync: nothing launches hidden, both classifications
    // must agree that the overlap is exactly zero
    let mut seq = cfg(DENSE, 1, 2, 1, ShardingStage::OptimizerStates, Dtype::F32);
    seq.overlap_grad_sync = false;
    seq.trace_out = Some(dir.join("trace_seq.json"));
    let off = train(&seq).expect("sequential traced run");
    let sq = off.trace_summary.as_ref().unwrap();
    assert_eq!(sq.dp_overlap, 0.0, "sequential sync classifies as fully exposed");
    assert_eq!(off.dp_overlap_fraction(), 0.0, "engine agrees: nothing hidden");
    std::fs::remove_dir_all(&dir).ok();
}
