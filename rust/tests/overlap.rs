//! Backward-overlapped DP gradient sync: correctness + the measured-
//! overlap perf contract.
//!
//! The engine launches each chunk's gradient buckets (nonblocking
//! all-reduce) as soon as the chunk's last micro-batch backward
//! finishes and drains them before the Adam step.  Because the bucketed
//! all-reduce sums in rank order no matter when deposits arrive, the
//! overlapped and sequential paths must walk **bit-identical** loss
//! trajectories — across DDP, ZeRO-1, tensor parallelism and virtual
//! chunks.  The perf side: the engine's measured hidden/exposed sync
//! seconds, run through `perf::dp_overlap_fraction`, must price the
//! model's exposed DP comm term within 10% (the overlap analogue of the
//! PR-2 TP byte pin).

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::perf::{dp_overlap_fraction, PerfModel};
use frontier_llm::runtime::BuiltinSpec;
use frontier_llm::zero::ShardingStage;

/// 20-step run with the overlap knobs under test; `grad_bucket_floats`
/// is small enough that every tiny stage splits into many buckets.
fn run(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    zero1: bool,
    sched: ScheduleKind,
    overlap: bool,
) -> TrainReport {
    let cfg = EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        schedule: sched,
        microbatches: m,
        steps: 20,
        zero_stage: if zero1 { ShardingStage::OptimizerStates } else { ShardingStage::Ddp },
        overlap_grad_sync: overlap,
        grad_bucket_floats: 64,
        seed: 42,
        ..Default::default()
    };
    train(&cfg).expect("training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn grad_norms(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.grad_norm).collect()
}

/// THE overlap invariant: bit-identical trajectories, overlapped vs
/// sequential, for every parallelisation the engine supports.
#[test]
fn overlapped_sync_is_bit_identical_to_sequential() {
    let cases: &[(&str, usize, usize, bool, ScheduleKind)] = &[
        // plain DDP, 2-stage pipeline × dp2
        ("builtin:tiny-s2-mb2", 1, 2, false, ScheduleKind::OneF1B),
        // ZeRO-1 sharded optimizer
        ("builtin:tiny-s2-mb2", 1, 2, true, ScheduleKind::OneF1B),
        // tensor parallel × data parallel
        ("builtin:tiny-s2-mb2", 2, 2, false, ScheduleKind::OneF1B),
        // virtual chunks (v=2) × dp2 with ZeRO-1
        ("builtin:tiny-s4-mb2", 1, 2, true, ScheduleKind::Interleaved1F1B { v: 2 }),
    ];
    for &(bundle, tp, dp, zero1, sched) in cases {
        let overlapped = run(bundle, tp, dp, 2, zero1, sched, true);
        let sequential = run(bundle, tp, dp, 2, zero1, sched, false);
        assert_eq!(
            losses(&overlapped),
            losses(&sequential),
            "{bundle} tp{tp} dp{dp} zero1={zero1}: loss trajectories must be bit-identical"
        );
        assert_eq!(
            grad_norms(&overlapped),
            grad_norms(&sequential),
            "{bundle} tp{tp} dp{dp} zero1={zero1}: grad norms must be bit-identical"
        );
    }
}

#[test]
fn overlapped_sync_is_deterministic() {
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    let a = run("builtin:tiny-s4-mb2", 1, 2, 2, false, sched, true);
    let b = run("builtin:tiny-s4-mb2", 1, 2, 2, false, sched, true);
    assert_eq!(losses(&a), losses(&b), "overlapped engine must be deterministic");
}

#[test]
fn bucket_size_does_not_change_numerics() {
    // rank-order reduction is elementwise, so bucketing cannot move the
    // trajectory: one bucket per stage vs dozens must agree exactly
    let mk = |bucket: usize| {
        let cfg = EngineConfig {
            bundle: "builtin:tiny-s2-mb2".into(),
            dp: 2,
            microbatches: 2,
            steps: 10,
            grad_bucket_floats: bucket,
            seed: 42,
            ..Default::default()
        };
        train(&cfg).expect("training must succeed")
    };
    let coarse = mk(1 << 20);
    let fine = mk(32);
    assert_eq!(losses(&coarse), losses(&fine), "bucket size changed the trajectory");
}

/// The measured-overlap perf contract at dp ∈ {2, 4}, in two halves:
///
/// 1. **Hard pin (PR-2 style):** the engine-measured nonblocking
///    bucket-round count must equal the analytic count derived from the
///    bundle spec — `steps × Σ_stages ⌈params / grad_bucket_floats⌉` —
///    EXACTLY, independent of dp and of overlap timing.
/// 2. **Timing plumbing:** the engine's (raw, exposed) sync seconds
///    must be structurally sane (exposed ≤ raw, overlap mode hides
///    work, sequential mode hides none), and feeding the measured
///    fraction through the shared `perf::dp_overlap_fraction` contract
///    into `PerfModel` must reprice the engine's exposed term within
///    10% of raw.
#[test]
fn measured_overlap_matches_model_term() {
    // analytic bucket-round count for builtin:tiny-s4-mb2 at the test's
    // grad_bucket_floats = 64, summed over the 4 global stages
    let spec = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
    let rounds_per_step: u64 =
        (0..spec.n_stages).map(|g| spec.stage_params(g).div_ceil(64) as u64).sum();

    for dp in [2usize, 4] {
        let sched = ScheduleKind::Interleaved1F1B { v: 2 };
        let r = run("builtin:tiny-s4-mb2", 1, dp, 4, false, sched, true);

        // 1. the hard pin: measured rounds == analytic bucket count
        assert_eq!(
            r.dp_bucket_rounds,
            20 * rounds_per_step,
            "dp={dp}: engine bucket rounds vs analytic count"
        );

        // 2. timing plumbing
        let raw = r.dp_sync_raw_s();
        let exposed = r.dp_sync_exposed_s;
        assert!(raw > 0.0, "dp={dp}: DP sync must be measured");
        assert!(exposed <= raw + 1e-12, "dp={dp}: exposed {exposed} > raw {raw}");
        assert!(
            r.dp_sync_hidden_s > 0.0,
            "dp={dp}: overlap mode must hide some sync work under backward"
        );
        let fraction = r.dp_overlap_fraction();
        assert!((0.0..=1.0).contains(&fraction), "dp={dp}: fraction {fraction}");
        assert_eq!(fraction, dp_overlap_fraction(raw, exposed), "shared contract fn");
        let model = PerfModel::default().with_dp_overlap(fraction);
        let priced = model.dp_exposed_comm_time(raw);
        assert!(
            (priced - exposed).abs() <= 0.10 * raw,
            "dp={dp}: model prices {priced}s exposed vs engine-measured {exposed}s (raw {raw}s)"
        );
    }

    // sequential mode launches everything post-stream: nothing hidden,
    // and the SAME bucket rounds (launch timing cannot change the count)
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    let r = run("builtin:tiny-s4-mb2", 1, 2, 4, false, sched, false);
    assert_eq!(r.dp_bucket_rounds, 20 * rounds_per_step, "sequential rounds");
    assert_eq!(r.dp_sync_hidden_s, 0.0, "sequential sync must hide nothing");
    assert_eq!(r.dp_overlap_fraction(), 0.0);
    assert!(r.dp_sync_exposed_s > 0.0);
}

#[test]
fn dp1_measures_no_dp_sync() {
    let r = run("builtin:tiny-s2-mb2", 1, 1, 2, false, ScheduleKind::OneF1B, true);
    assert_eq!(r.dp_sync_raw_s(), 0.0);
    assert_eq!(r.dp_overlap_fraction(), 0.0);
    assert_eq!(r.dp_bucket_rounds, 0, "dp=1 launches no buckets");
}
