//! Crash-consistent checkpointing tests: generation directories,
//! checksummed atomic commits, last-good fallback, and the async save
//! path that overlaps training.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **Generations** — saves land in `gen-<step>/` via a staged write +
//!   one atomic rename; `--ckpt-keep N` retains a chain and prunes the
//!   rest; resume scans for the newest *committed* generation.
//! * **Crash during save** — `--fault ckpt-crash@g:r` kills rank `r`
//!   inside the save of generation `g` on both save paths; the torn
//!   staging dir is never eligible and recovery resumes **bitwise
//!   identically** from the last committed generation.
//! * **Corruption fallback** — truncating or bit-flipping any file class
//!   (params / optimizer / manifest) of the newest generation makes the
//!   scan fall back to the previous one, again bitwise.
//! * **Async ≡ sync** — `--async-checkpoint` persists on a background
//!   saver thread; the training trajectory AND the committed bytes are
//!   bitwise identical to sync saves.
//! * **Write retry** — `--fault write-fail@g:r:n` injects transient
//!   write failures; n below the retry budget is invisible bitwise,
//!   exhausting the budget is a hard error naming the failed file.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::checkpoint::{gen_dir, latest_committed};
use frontier_llm::coordinator::{train, EngineConfig, FaultSpec, TrainReport};
use frontier_llm::precision::Dtype;
use frontier_llm::zero::ShardingStage;

const S1: ShardingStage = ShardingStage::OptimizerStates;

/// Generous next to a sub-millisecond step, tiny next to a hang: the
/// survivors of a mid-save crash stall this long, once, then recover.
const TIMEOUT_MS: u64 = 2000;

fn cfg(dp: usize, steps: u32) -> EngineConfig {
    EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp,
        tp: 1,
        schedule: ScheduleKind::OneF1B,
        microbatches: 2,
        steps,
        zero_stage: S1,
        precision: Dtype::F32,
        grad_bucket_floats: 128,
        seed: 42,
        ..Default::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fllm-ckpt-{tag}-{}", std::process::id()))
}

/// Bitwise view of a trajectory: step index, loss, grad-norm and
/// loss-scale bits, skip flag.
fn traj(r: &TrainReport) -> Vec<(u32, u32, u32, u32, bool)> {
    r.logs
        .iter()
        .map(|l| {
            (l.step, l.loss.to_bits(), l.grad_norm.to_bits(), l.loss_scale.to_bits(), l.skipped)
        })
        .collect()
}

fn dir_names(dir: &Path) -> BTreeSet<String> {
    std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect()
}

/// Assert two committed generation directories hold byte-identical
/// files (MANIFEST.json included).
fn assert_dirs_bitwise_equal(a: &Path, b: &Path, tag: &str) {
    let names = dir_names(a);
    assert_eq!(names, dir_names(b), "{tag}: {a:?} and {b:?} hold the same file set");
    for name in names {
        assert_eq!(
            std::fs::read(a.join(&name)).unwrap(),
            std::fs::read(b.join(&name)).unwrap(),
            "{tag}: {name} must be byte-identical across {a:?} and {b:?}"
        );
    }
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for e in std::fs::read_dir(src).unwrap() {
        let e = e.unwrap();
        let to = dst.join(e.file_name());
        if e.path().is_dir() {
            copy_dir(&e.path(), &to);
        } else {
            std::fs::copy(e.path(), &to).unwrap();
        }
    }
}

// =========================================================================
// Generations: commit chain, retention, resume scan
// =========================================================================

#[test]
fn saves_commit_a_generation_chain_and_keep_prunes_it() {
    let dir = tmp("chain");
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = cfg(2, 8);
    c.checkpoint_dir = Some(dir.clone());
    c.checkpoint_every = 2;
    c.ckpt_keep = 3;
    train(&c).expect("checkpointed run succeeds");

    // saves at manifest steps 2, 4, 6, 8; keep = 3 retires gen-2
    let names = dir_names(&dir);
    assert!(!names.contains("gen-2"), "oldest generation pruned, got {names:?}");
    for g in ["gen-4", "gen-6", "gen-8"] {
        assert!(names.contains(g), "{g} must survive --ckpt-keep 3, got {names:?}");
    }
    assert!(
        names.iter().all(|n| !n.ends_with(".tmp")),
        "no staging dirs survive a clean run, got {names:?}"
    );

    let got = latest_committed(&dir).unwrap().expect("a committed generation exists");
    assert_eq!(got.dir, gen_dir(&dir, 8), "resume scan picks the newest generation");
    assert_eq!(got.manifest.step, 8);
    assert!(
        !got.manifest.files.is_empty(),
        "the committed manifest lists every file with size + crc32"
    );
    std::fs::remove_dir_all(&dir).ok();
}

// =========================================================================
// Async ≡ sync: bitwise trajectory AND bitwise committed bytes
// =========================================================================

#[test]
fn async_saves_match_sync_bitwise_on_disk_and_in_trajectory() {
    let dir_s = tmp("eq-sync");
    let dir_a = tmp("eq-async");
    let _ = std::fs::remove_dir_all(&dir_s);
    let _ = std::fs::remove_dir_all(&dir_a);

    let mut s = cfg(2, 4);
    s.checkpoint_dir = Some(dir_s.clone());
    s.checkpoint_every = 2;
    let s = train(&s).expect("sync-checkpointed run succeeds");

    let mut a = cfg(2, 4);
    a.checkpoint_dir = Some(dir_a.clone());
    a.checkpoint_every = 2;
    a.async_checkpoint = true;
    let a = train(&a).expect("async-checkpointed run succeeds");

    assert_eq!(traj(&a), traj(&s), "the saver thread must not perturb the trajectory");
    // both runs keep the default 2-generation chain: compare every byte
    assert_eq!(dir_names(&dir_a), dir_names(&dir_s));
    for g in [2u32, 4] {
        assert_dirs_bitwise_equal(&gen_dir(&dir_a, g), &gen_dir(&dir_s, g), "async-vs-sync");
    }
    // timer classification: sync persists inline (all exposed), async
    // drains the writes on the saver thread (hidden time appears)
    assert!(s.ckpt_save_exposed_ms > 0.0, "sync saves expose their write time");
    assert_eq!(s.ckpt_save_hidden_ms, 0.0, "sync saves have no saver thread to hide on");
    assert!(a.ckpt_save_hidden_ms > 0.0, "async saves drain on the saver thread");

    std::fs::remove_dir_all(&dir_s).ok();
    std::fs::remove_dir_all(&dir_a).ok();
}

// =========================================================================
// ckpt-crash: a rank dies inside the save; the torn generation never
// commits and recovery resumes bitwise from the last committed one
// =========================================================================

/// Three runs (the elastic P/A/B scheme, crash-during-save edition):
///
/// * **P** — dp = 2 for 2 steps; its step-2 generation is the state any
///   fresh smaller world would resume from.
/// * **A** — dp = 2 for 6 steps, rank 1 killed *inside* the save of
///   generation 4 (end of step 3).  gen-4 stays a torn staging dir, so
///   recovery falls back to committed gen-2 at dp = 1 and recomputes
///   from step 2.
/// * **B** — a fresh dp = 1 run resuming from P's checkpoint for the
///   remaining 4 steps.
///
/// Locks: A ≡ P bitwise before the crash, A ≡ B bitwise after recovery.
fn ckpt_crash_scheme(async_ckpt: bool, lost: u64, tag: &str) {
    let dir_p = tmp(&format!("{tag}-p"));
    let dir_a = tmp(&format!("{tag}-a"));
    let _ = std::fs::remove_dir_all(&dir_p);
    let _ = std::fs::remove_dir_all(&dir_a);

    let mut p = cfg(2, 2);
    p.checkpoint_dir = Some(dir_p.clone());
    p.checkpoint_every = 2;
    p.async_checkpoint = async_ckpt;
    let p = train(&p).expect("straight run must succeed");

    let mut a = cfg(2, 6);
    a.checkpoint_dir = Some(dir_a.clone());
    a.checkpoint_every = 2;
    a.async_checkpoint = async_ckpt;
    a.faults = FaultSpec::parse_list("ckpt-crash@4:1").unwrap();
    a.comm_timeout_ms = TIMEOUT_MS;
    let a = train(&a).expect("the crashed save must recover, not error");

    assert_eq!(a.recovery_events, 1, "{tag}: one crash, one recovery");
    // sync: the head rank blocks at the commit barrier before reporting
    // step 3, so only logged step 2 is recomputed; async: the hand-off
    // never blocks the head, step 3 is logged and recomputed too
    assert_eq!(a.lost_steps, lost, "{tag}: steps past the gen-2 fallback are recomputed");
    assert_eq!(a.world_size, 2, "{tag}: the run finishes on the shrunken world (pp2 x dp1)");
    assert_eq!(
        a.logs.iter().map(|l| l.step).collect::<Vec<_>>(),
        (0..6).collect::<Vec<_>>(),
        "{tag}: the stitched log covers every step exactly once"
    );

    let mut b = cfg(1, 4);
    b.checkpoint_dir = Some(dir_p.clone());
    b.resume = true;
    let b = train(&b).expect("fresh run at the smaller world must succeed");

    assert_eq!(traj(&a)[..2], traj(&p)[..], "{tag}: pre-crash leg ≡ straight dp = 2 run");
    assert_eq!(
        traj(&a)[2..],
        traj(&b)[..],
        "{tag}: post-recovery trajectory ≡ fresh dp = 1 resume from gen-2, bitwise"
    );

    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_a).ok();
}

#[test]
fn ckpt_crash_sync_falls_back_to_last_committed_generation() {
    ckpt_crash_scheme(false, 1, "crash-sync");
}

#[test]
fn ckpt_crash_async_falls_back_to_last_committed_generation() {
    ckpt_crash_scheme(true, 2, "crash-async");
}

// =========================================================================
// Corruption fallback: every file class, truncated and bit-flipped
// =========================================================================

#[test]
fn corruption_of_the_newest_generation_falls_back_to_last_good() {
    let pristine = tmp("corrupt-src");
    let _ = std::fs::remove_dir_all(&pristine);
    let mut c = cfg(2, 4);
    c.checkpoint_dir = Some(pristine.clone());
    c.checkpoint_every = 2;
    c.ckpt_keep = 4;
    train(&c).expect("setup run succeeds");
    assert_eq!(
        latest_committed(&pristine).unwrap().unwrap().dir,
        gen_dir(&pristine, 4),
        "pristine chain resolves to gen-4"
    );

    // the reference: what a resume from gen-2 alone produces
    let reference = {
        let root = tmp("corrupt-ref");
        let _ = std::fs::remove_dir_all(&root);
        copy_dir(&pristine, &root);
        std::fs::remove_dir_all(gen_dir(&root, 4)).unwrap();
        let mut r = cfg(2, 2);
        r.checkpoint_dir = Some(root.clone());
        r.resume = true;
        let r = train(&r).expect("reference resume from gen-2 succeeds");
        std::fs::remove_dir_all(&root).ok();
        traj(&r)
    };

    fn pick(root: &Path, suffix: &str) -> PathBuf {
        let gen = gen_dir(root, 4);
        let mut names: Vec<String> = dir_names(&gen)
            .into_iter()
            .filter(|n| n.ends_with(suffix))
            .collect();
        names.sort();
        gen.join(names.first().unwrap_or_else(|| panic!("no {suffix} file in {gen:?}")))
    }
    fn truncate(p: PathBuf) {
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
    }
    fn bit_flip(p: PathBuf) {
        let mut bytes = std::fs::read(&p).unwrap();
        *bytes.last_mut().unwrap() ^= 0x01; // payload byte: CRC32 must catch it
        std::fs::write(&p, &bytes).unwrap();
    }

    type Corrupt<'a> = (&'a str, fn(&Path));
    let matrix: Vec<Corrupt> = vec![
        ("params-truncated", |r| truncate(pick(r, ".params.bin"))),
        ("params-bit-flip", |r| bit_flip(pick(r, ".params.bin"))),
        ("opt-truncated", |r| truncate(pick(r, ".opt.bin"))),
        ("opt-bit-flip", |r| bit_flip(pick(r, ".opt.bin"))),
        ("manifest-truncated", |r| truncate(pick(r, "MANIFEST.json"))),
        ("manifest-missing", |r| {
            std::fs::remove_file(gen_dir(r, 4).join("MANIFEST.json")).unwrap()
        }),
    ];
    for (tag, corrupt) in matrix {
        let root = tmp(&format!("corrupt-{tag}"));
        let _ = std::fs::remove_dir_all(&root);
        copy_dir(&pristine, &root);
        corrupt(&root);
        let resolved = latest_committed(&root).unwrap().expect("gen-2 still resolves");
        assert_eq!(resolved.dir, gen_dir(&root, 2), "{tag}: the scan skips corrupt gen-4");
        let mut r = cfg(2, 2);
        r.checkpoint_dir = Some(root.clone());
        r.resume = true;
        let r = train(&r).unwrap_or_else(|e| panic!("{tag}: fallback resume failed: {e:#}"));
        assert_eq!(
            traj(&r),
            reference,
            "{tag}: resume past the corrupt generation ≡ resume from gen-2, bitwise"
        );
        std::fs::remove_dir_all(&root).ok();
    }
    std::fs::remove_dir_all(&pristine).ok();
}

// =========================================================================
// write-fail: transient failures retry invisibly, exhaustion is hard
// =========================================================================

#[test]
fn transient_write_failures_retry_bitwise_invisibly_on_both_paths() {
    let dir_n = tmp("wf-none");
    let _ = std::fs::remove_dir_all(&dir_n);
    let mut n = cfg(2, 4);
    n.checkpoint_dir = Some(dir_n.clone());
    n.checkpoint_every = 2;
    let n = train(&n).expect("fault-free run succeeds");

    for (tag, async_ckpt) in [("wf-sync", false), ("wf-async", true)] {
        let dir_f = tmp(tag);
        let _ = std::fs::remove_dir_all(&dir_f);
        let mut f = cfg(2, 4);
        f.checkpoint_dir = Some(dir_f.clone());
        f.checkpoint_every = 2;
        f.async_checkpoint = async_ckpt;
        // 3 failures fit inside the 5-attempt retry budget: invisible
        f.faults = FaultSpec::parse_list("write-fail@2:0:3").unwrap();
        let f = train(&f).expect("retried writes must not surface");
        assert_eq!(f.recovery_events, 0, "{tag}: a retried write is not a recovery");
        assert_eq!(traj(&f), traj(&n), "{tag}: retries are invisible to the trajectory");
        for g in [2u32, 4] {
            assert_dirs_bitwise_equal(&gen_dir(&dir_f, g), &gen_dir(&dir_n, g), tag);
        }
        std::fs::remove_dir_all(&dir_f).ok();
    }
    std::fs::remove_dir_all(&dir_n).ok();
}

#[test]
fn exhausting_the_write_retry_budget_is_a_hard_error() {
    for (tag, async_ckpt) in [("wx-sync", false), ("wx-async", true)] {
        let dir = tmp(tag);
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = cfg(2, 4);
        c.checkpoint_dir = Some(dir.clone());
        c.checkpoint_every = 2;
        c.async_checkpoint = async_ckpt;
        // a 20-failure budget outlasts the 5 write attempts
        c.faults = FaultSpec::parse_list("write-fail@2:0:20").unwrap();
        // no rank is killed, so nothing auto-arms the bounded waits; the
        // sync path's survivors sit at the commit barrier until then
        c.comm_timeout_ms = TIMEOUT_MS;
        let err = match train(&c) {
            Ok(_) => panic!("{tag}: an untrustable save must tear down, not succeed"),
            Err(e) => e,
        };
        let chain = format!("{err:#}");
        assert!(
            chain.contains("failed after"),
            "{tag}: the error names the exhausted retry budget: {chain}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}
