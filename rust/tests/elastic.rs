//! Elastic fault-tolerance tests: bounded collective waits, deterministic
//! fault injection, and dp±1 world reconfiguration from the checkpoint
//! manifest.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **Bounded waits** — a collective wait on an absent peer surfaces the
//!   typed [`PeerLost`] panic payload after the armed deadline instead of
//!   hanging forever, and the diagnostic names the missing rank and tag.
//! * **Deterministic kill** — `--fault kill@k:r` kills world rank `r` at
//!   the top of step `k`, before any collective of that step, on every
//!   run; re-running the faulted config reproduces the whole trajectory
//!   bitwise.
//! * **Bounded loss** — after a kill at dp = d the coordinator stops the
//!   world at the last manifest and restarts at dp = d − 1; the
//!   post-recovery trajectory is **bitwise identical** to a fresh run
//!   launched at dp = d − 1 from the same checkpoint, and at most
//!   `checkpoint_every` steps are recomputed (`lost_steps`).
//! * **dp re-partitioning** — ZeRO optimizer shards (m ++ v, plus fp32
//!   masters under bf16) re-slice exactly across dp 2 ↔ 3 ↔ 4.
//! * **Planned join** — `join@k` checkpoints at step k and restarts at
//!   dp + 1; the result equals save-then-resume at the larger world.
//!
//! The full kill@k × stage ∈ {0,1,2,3} × precision ∈ {fp32, bf16} ×
//! dp ∈ {2,3,4} grid rides behind `--features fault-matrix` (CI).

use std::path::PathBuf;
use std::time::Instant;

use frontier_llm::collectives::{chunk_bounds, Group, PeerLost};
use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::checkpoint::{opt_path, reslice_opt_state, write_f32};
use frontier_llm::coordinator::{train, EngineConfig, FaultSpec, TrainReport};
use frontier_llm::precision::Dtype;
use frontier_llm::zero::ShardingStage;

const S1: ShardingStage = ShardingStage::OptimizerStates;
const S2: ShardingStage = ShardingStage::Gradients;

/// Deadline generous next to a (sub-millisecond) tiny step, tiny next to
/// a hang: survivors of a kill stall this long, once, then recover.
const TIMEOUT_MS: u64 = 2000;

fn cfg(dp: usize, steps: u32, stage: ShardingStage, precision: Dtype) -> EngineConfig {
    EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp,
        tp: 1,
        schedule: ScheduleKind::OneF1B,
        microbatches: 2,
        steps,
        zero_stage: stage,
        precision,
        grad_bucket_floats: 128,
        seed: 42,
        // a short scaler cadence so bf16 runs carry *evolving* loss-scale
        // state across the recovery boundary, not a constant
        loss_scale_init: if precision == Dtype::Bf16 { 1024.0 } else { 1.0 },
        loss_scale_growth_interval: 2,
        ..Default::default()
    }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fllm-elastic-{tag}-{}", std::process::id()))
}

/// Bitwise view of a trajectory: step index, loss, grad-norm and
/// loss-scale bits, skip flag.
fn traj(r: &TrainReport) -> Vec<(u32, u32, u32, u32, bool)> {
    r.logs
        .iter()
        .map(|l| {
            (l.step, l.loss.to_bits(), l.grad_norm.to_bits(), l.loss_scale.to_bits(), l.skipped)
        })
        .collect()
}

// =========================================================================
// Detection: bounded waits surface PeerLost instead of hanging
// =========================================================================

#[test]
fn bounded_barrier_surfaces_peer_lost_instead_of_hanging() {
    let g = Group::new(2);
    g.set_comm_timeout(200);
    let g2 = g.clone();
    let start = Instant::now();
    // rank 0 enters the barrier; rank 1 never exists
    let h = std::thread::spawn(move || g2.barrier(0));
    let err = h.join().expect_err("a barrier missing a peer must not return");
    let lost = err.downcast_ref::<PeerLost>().expect("panic payload is the typed PeerLost");
    assert_eq!(lost.rank, Some(1), "the diagnostic names the missing rank");
    assert_eq!(lost.waited_ms, 200, "the diagnostic carries the armed deadline");
    assert!(
        start.elapsed().as_secs() < 30,
        "the wait is bounded by the deadline, not by the test harness"
    );
    assert!(lost.to_string().contains("peer rank 1"), "display names the peer: {lost}");
}

#[test]
fn bounded_p2p_recv_names_the_absent_sender_and_tag() {
    let g = Group::new(2);
    g.set_comm_timeout(200);
    let g2 = g.clone();
    let h = std::thread::spawn(move || {
        let _ = g2.recv_shared(0, 1, 7);
    });
    let err = h.join().expect_err("a p2p recv from an absent sender must not return");
    let lost = err.downcast_ref::<PeerLost>().expect("panic payload is the typed PeerLost");
    assert_eq!(lost.rank, Some(1));
    assert_eq!(lost.tag, 7);
    assert_eq!(lost.what, "p2p recv");
}

#[test]
fn zero_timeout_means_unbounded_and_is_the_default() {
    let g = Group::new(2);
    assert_eq!(g.comm_timeout_ms(), 0, "groups are born with no deadline armed");
    g.set_comm_timeout(150);
    assert_eq!(g.comm_timeout_ms(), 150);
}

// =========================================================================
// Fault grammar
// =========================================================================

#[test]
fn fault_spec_parses_the_cli_grammar() {
    assert_eq!(FaultSpec::parse("kill@3:1"), Some(FaultSpec::Kill { step: 3, rank: 1 }));
    assert_eq!(FaultSpec::parse("join@5"), Some(FaultSpec::Join { step: 5 }));
    assert_eq!(
        FaultSpec::parse("ckpt-crash@4:0"),
        Some(FaultSpec::CkptCrash { step: 4, rank: 0 })
    );
    assert_eq!(
        FaultSpec::parse("write-fail@6:1:3"),
        Some(FaultSpec::WriteFail { step: 6, rank: 1, count: 3 })
    );
    for bad in [
        "kill@3",
        "kill@x:1",
        "kill@3:",
        "kill@:1",
        "kill@3:1:2",
        "join@",
        "join@x",
        "restart@2",
        "",
        "ckpt-crash@4",
        "ckpt-crash@4:0:1",
        "write-fail@6:1",
        "write-fail@6:1:x",
    ] {
        assert_eq!(FaultSpec::parse(bad), None, "{bad:?} must be rejected");
    }
}

#[test]
fn fault_list_parses_commas_and_rejects_duplicate_steps() {
    assert_eq!(
        FaultSpec::parse_list("kill@5:1,ckpt-crash@8:0"),
        Ok(vec![
            FaultSpec::Kill { step: 5, rank: 1 },
            FaultSpec::CkptCrash { step: 8, rank: 0 },
        ])
    );
    // whitespace around items is tolerated
    assert_eq!(
        FaultSpec::parse_list(" join@2 , write-fail@4:0:2 "),
        Ok(vec![
            FaultSpec::Join { step: 2 },
            FaultSpec::WriteFail { step: 4, rank: 0, count: 2 },
        ])
    );
    // malformed items and empty list entries are errors, not silently dropped
    assert!(FaultSpec::parse_list("kill@3:1,bogus@2").is_err());
    assert!(FaultSpec::parse_list("kill@3:1,,join@5").is_err());
    assert!(FaultSpec::parse_list("").is_err());
    // two faults at the same step would race nondeterministically: rejected
    let dup = FaultSpec::parse_list("kill@3:1,ckpt-crash@3:0");
    assert!(dup.as_ref().is_err(), "duplicate step must be rejected, got {dup:?}");
    assert!(dup.unwrap_err().contains("duplicate"), "the error names the duplication");
}

// =========================================================================
// dp re-partitioning of optimizer state, unit level
// =========================================================================

#[test]
fn reslice_chain_round_trips_across_dp_2_3_4() {
    let n = 23usize; // deliberately not divisible by 2, 3 or 4
    for comp in [2usize, 3] {
        // 2 components = fp32 (m ++ v); 3 = bf16 (+ fp32 masters)
        let dir = tmp(&format!("chain{comp}"));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // distinct value per (component, index) so misplacement is visible
        let full: Vec<Vec<f32>> = (0..comp)
            .map(|k| (0..n).map(|i| (k * 1000 + i) as f32 + 0.5).collect())
            .collect();
        let shard = |dp: usize, r: usize| -> Vec<f32> {
            let (lo, hi) = chunk_bounds(n, dp)[r];
            full.iter().flat_map(|c| c[lo..hi].to_vec()).collect()
        };
        for r in 0..2 {
            write_f32(&opt_path(&dir, 0, 0, r), &shard(2, r), 7).unwrap();
        }
        let mut old_dp = 2usize;
        for new_dp in [3usize, 4, 2] {
            let resliced: Vec<(Vec<f32>, u64)> = (0..new_dp)
                .map(|r| reslice_opt_state(&dir, 0, 0, old_dp, new_dp, r, n).unwrap())
                .collect();
            for (r, (s, t)) in resliced.iter().enumerate() {
                assert_eq!(*t, 7, "Adam step counter survives re-slicing");
                assert_eq!(s, &shard(new_dp, r), "dp {old_dp} → {new_dp}, rank {r}");
                write_f32(&opt_path(&dir, 0, 0, r), s, *t).unwrap();
            }
            old_dp = new_dp;
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

// =========================================================================
// THE acceptance lock: kill at dp = d, recover at dp = d − 1, and the
// post-recovery trajectory is bitwise a fresh run at the smaller world
// =========================================================================

/// Three runs:
///
/// * **P** — straight dp = d for 2 steps, manifest at step 2 (the
///   checkpoint a fresh smaller world would start from).
/// * **A** — dp = d for 6 steps with rank 1 killed at the top of step 3.
///   Checkpoints land every 2 steps, so the last manifest before the kill
///   is step 2: step 2's completed work is lost and recomputed.
/// * **B** — a fresh run launched at dp = d − 1 resuming from P's
///   checkpoint for the remaining 4 steps.
///
/// Locks: A ≡ P bitwise before the kill, A ≡ B bitwise after recovery,
/// exactly one recovery event, exactly one recomputed step.
fn kill_recovery_scheme(stage: ShardingStage, precision: Dtype, d: usize, tag: &str) {
    let dir_p = tmp(&format!("{tag}-p"));
    let dir_a = tmp(&format!("{tag}-a"));
    let _ = std::fs::remove_dir_all(&dir_p);
    let _ = std::fs::remove_dir_all(&dir_a);

    let mut p = cfg(d, 2, stage, precision);
    p.checkpoint_dir = Some(dir_p.clone());
    p.checkpoint_every = 2;
    let p = train(&p).expect("straight run must succeed");

    let mut a = cfg(d, 6, stage, precision);
    a.checkpoint_dir = Some(dir_a.clone());
    a.checkpoint_every = 2;
    a.faults = FaultSpec::parse_list("kill@3:1").unwrap();
    a.comm_timeout_ms = TIMEOUT_MS;
    let a = train(&a).expect("the faulted run must recover, not error");

    assert_eq!(a.recovery_events, 1, "{tag}: one kill, one recovery");
    assert_eq!(a.lost_steps, 1, "{tag}: only step 2 (past the step-2 manifest) is recomputed");
    assert_eq!(a.world_size, 2 * (d - 1), "{tag}: the run finishes on the shrunken world");
    assert_eq!(
        a.logs.iter().map(|l| l.step).collect::<Vec<_>>(),
        (0..6).collect::<Vec<_>>(),
        "{tag}: the stitched log covers every step exactly once"
    );

    let mut b = cfg(d - 1, 4, stage, precision);
    b.checkpoint_dir = Some(dir_p.clone());
    b.resume = true;
    let b = train(&b).expect("fresh run at the smaller world must succeed");

    assert_eq!(traj(&a)[..2], traj(&p)[..], "{tag}: pre-kill leg ≡ straight dp = {d} run");
    assert_eq!(
        traj(&a)[2..],
        traj(&b)[..],
        "{tag}: post-recovery trajectory ≡ fresh dp = {} run from the checkpoint, bitwise",
        d - 1
    );

    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_a).ok();
}

#[test]
fn kill_recovery_matches_fresh_run_at_the_smaller_world() {
    kill_recovery_scheme(S2, Dtype::F32, 3, "base-s2-fp32");
}

#[test]
fn kill_recovery_is_deterministic_across_reruns() {
    let runs: Vec<TrainReport> = (0..2)
        .map(|i| {
            let dir = tmp(&format!("det{i}"));
            let _ = std::fs::remove_dir_all(&dir);
            let mut a = cfg(3, 6, S1, Dtype::F32);
            a.checkpoint_dir = Some(dir.clone());
            a.checkpoint_every = 2;
            a.faults = FaultSpec::parse_list("kill@3:1").unwrap();
            a.comm_timeout_ms = TIMEOUT_MS;
            let r = train(&a).expect("faulted run must recover");
            std::fs::remove_dir_all(&dir).ok();
            r
        })
        .collect();
    assert_eq!(traj(&runs[0]), traj(&runs[1]), "the injected fault replays bitwise");
    assert_eq!(runs[0].recovery_events, runs[1].recovery_events);
    assert_eq!(runs[0].lost_steps, runs[1].lost_steps);
    assert_eq!(runs[0].final_loss_scale.to_bits(), runs[1].final_loss_scale.to_bits());
}

#[test]
fn kill_without_a_checkpoint_restarts_from_scratch() {
    // no --checkpoint: the shrunken world has no manifest to resume from,
    // so it restarts the run from step 0 — every completed step is lost
    let mut a = cfg(2, 3, S1, Dtype::F32);
    a.faults = FaultSpec::parse_list("kill@1:1").unwrap();
    a.comm_timeout_ms = TIMEOUT_MS;
    let a = train(&a).expect("recovery without a checkpoint restarts from scratch");
    assert_eq!(a.recovery_events, 1);
    assert_eq!(a.lost_steps, 1, "step 0 completed, then was discarded with the world");
    assert_eq!(a.world_size, 2, "pp = 2 × dp = 1");

    let b = train(&cfg(1, 3, S1, Dtype::F32)).expect("straight dp = 1 run");
    assert_eq!(traj(&a), traj(&b), "the scratch restart ≡ a straight dp = 1 run, bitwise");
}

// =========================================================================
// Planned join: dp + 1 from the step-k manifest
// =========================================================================

#[test]
fn planned_join_grows_the_world_and_matches_save_then_resume() {
    let dir_j = tmp("join-j");
    let dir_p = tmp("join-p");
    let _ = std::fs::remove_dir_all(&dir_j);
    let _ = std::fs::remove_dir_all(&dir_p);

    let mut j = cfg(2, 4, S1, Dtype::F32);
    j.checkpoint_dir = Some(dir_j.clone());
    j.checkpoint_every = 2;
    j.faults = FaultSpec::parse_list("join@2").unwrap();
    let j = train(&j).expect("planned join must succeed");
    assert_eq!(j.recovery_events, 1, "a join is a recovery event");
    assert_eq!(j.lost_steps, 0, "a planned join recomputes nothing");
    assert_eq!(j.world_size, 2 * 3, "the run finishes on the grown world");

    // the same thing by hand: save at 2, resume at dp = 3
    let mut p = cfg(2, 2, S1, Dtype::F32);
    p.checkpoint_dir = Some(dir_p.clone());
    p.checkpoint_every = 2;
    let p = train(&p).unwrap();
    let mut q = cfg(3, 2, S1, Dtype::F32);
    q.checkpoint_dir = Some(dir_p.clone());
    q.resume = true;
    let q = train(&q).unwrap();

    assert_eq!(traj(&j)[..2], traj(&p)[..], "pre-join leg ≡ straight dp = 2 run");
    assert_eq!(traj(&j)[2..], traj(&q)[..], "post-join leg ≡ manual dp = 3 resume, bitwise");

    std::fs::remove_dir_all(&dir_j).ok();
    std::fs::remove_dir_all(&dir_p).ok();
}

#[test]
fn join_without_a_checkpoint_dir_is_rejected() {
    let mut j = cfg(2, 4, S1, Dtype::F32);
    j.faults = FaultSpec::parse_list("join@2").unwrap();
    let err = train(&j).expect_err("join needs a manifest for the grown world");
    assert!(err.to_string().contains("--checkpoint"), "unexpected error: {err:#}");
}

// =========================================================================
// Expert parallelism under faults: the shrunken world re-slices the
// (expert-carrying) optimizer shards and falls back to ep = 1 when the
// new dp breaks divisibility — trajectories are ep-invariant, so the
// recovery still lands bitwise on the fresh-run reference
// =========================================================================

fn moe_cfg(dp: usize, ep: usize, steps: u32, stage: ShardingStage) -> EngineConfig {
    EngineConfig {
        bundle: "builtin:tiny-moe4k2-s2-mb2".into(),
        dp,
        ep,
        tp: 1,
        schedule: ScheduleKind::OneF1B,
        microbatches: 2,
        steps,
        zero_stage: stage,
        precision: Dtype::F32,
        grad_bucket_floats: 128,
        seed: 42,
        ..Default::default()
    }
}

#[test]
fn moe_kill_recovery_falls_back_to_ep1_and_matches_the_fresh_run() {
    // dp = 4 at ep = 2; the kill shrinks to dp = 3, which ep = 2 does
    // not divide, so the recovered world routes locally (ep = 1).  The
    // expert parameters ride the same flat vector as everything else, so
    // the dp 4 → 3 optimizer-shard re-slice needs no MoE-specific path.
    let dir_p = tmp("moe-p");
    let dir_a = tmp("moe-a");
    let _ = std::fs::remove_dir_all(&dir_p);
    let _ = std::fs::remove_dir_all(&dir_a);

    let mut p = moe_cfg(4, 2, 2, S2);
    p.checkpoint_dir = Some(dir_p.clone());
    p.checkpoint_every = 2;
    let p = train(&p).expect("straight MoE run must succeed");
    assert!(p.moe_a2a_rounds > 0, "ep = 2 must hit the a2a wire");

    let mut a = moe_cfg(4, 2, 6, S2);
    a.checkpoint_dir = Some(dir_a.clone());
    a.checkpoint_every = 2;
    a.faults = FaultSpec::parse_list("kill@3:1").unwrap();
    a.comm_timeout_ms = TIMEOUT_MS;
    let a = train(&a).expect("the faulted MoE run must recover");
    assert_eq!(a.recovery_events, 1);
    assert_eq!(a.world_size, 2 * 3, "the run finishes on the shrunken world");

    // the fresh reference at the smaller world: dp = 3 forces ep = 1
    let mut b = moe_cfg(3, 1, 4, S2);
    b.checkpoint_dir = Some(dir_p.clone());
    b.resume = true;
    let b = train(&b).expect("fresh dp = 3 run must resume the ep = 2 checkpoint");

    assert_eq!(traj(&a)[..2], traj(&p)[..], "pre-kill leg ≡ straight ep = 2 run");
    assert_eq!(
        traj(&a)[2..],
        traj(&b)[..],
        "post-recovery (ep fallback) ≡ fresh ep = 1 resume, bitwise"
    );

    std::fs::remove_dir_all(&dir_p).ok();
    std::fs::remove_dir_all(&dir_a).ok();
}

#[test]
fn moe_resume_rejects_expert_config_mismatch() {
    let dir = tmp("moe-rej");
    let _ = std::fs::remove_dir_all(&dir);
    let mut save = moe_cfg(2, 1, 2, S1);
    save.checkpoint_dir = Some(dir.clone());
    save.checkpoint_every = 2;
    train(&save).expect("saving run must succeed");

    let resume_as = |bundle: &str| {
        let mut c = moe_cfg(2, 1, 2, S1);
        c.bundle = bundle.into();
        c.checkpoint_dir = Some(dir.clone());
        c.resume = true;
        train(&c).expect_err("a different expert shape must hard-reject").to_string()
    };
    // more experts: parameter files cannot be re-assembled
    let err = resume_as("builtin:tiny-moe8k2-s2-mb2");
    assert!(err.contains("expert config"), "{err}");
    assert!(err.contains("experts=4"), "the error names the saved shape: {err}");
    // a top-k change alters routing silently: rejected the same way
    let err = resume_as("builtin:tiny-moe4k1-s2-mb2");
    assert!(err.contains("topk=2"), "{err}");
    // dense resume of an MoE checkpoint: the targeted expert-config
    // message beats the generic bundle mismatch
    let err = resume_as("builtin:tiny-s2-mb2");
    assert!(err.contains("expert config"), "{err}");

    std::fs::remove_dir_all(&dir).ok();
}

// =========================================================================
// The full grid: kill@3 × stage ∈ {0,1,2,3} × {fp32, bf16} × dp ∈ {2,3,4}
// (CI: `cargo test --features fault-matrix --test elastic elastic_matrix`)
// =========================================================================

#[cfg(feature = "fault-matrix")]
mod fault_matrix {
    use super::*;

    const S0: ShardingStage = ShardingStage::Ddp;
    const S3: ShardingStage = ShardingStage::Parameters;

    #[test]
    fn elastic_matrix_s0_fp32() {
        kill_recovery_scheme(S0, Dtype::F32, 3, "m-s0-fp32");
    }

    #[test]
    fn elastic_matrix_s1_fp32() {
        kill_recovery_scheme(S1, Dtype::F32, 3, "m-s1-fp32");
    }

    #[test]
    fn elastic_matrix_s3_fp32() {
        kill_recovery_scheme(S3, Dtype::F32, 3, "m-s3-fp32");
    }

    #[test]
    fn elastic_matrix_s0_bf16() {
        kill_recovery_scheme(S0, Dtype::Bf16, 3, "m-s0-bf16");
    }

    #[test]
    fn elastic_matrix_s1_bf16() {
        kill_recovery_scheme(S1, Dtype::Bf16, 3, "m-s1-bf16");
    }

    #[test]
    fn elastic_matrix_s2_bf16() {
        kill_recovery_scheme(S2, Dtype::Bf16, 3, "m-s2-bf16");
    }

    #[test]
    fn elastic_matrix_s3_bf16() {
        kill_recovery_scheme(S3, Dtype::Bf16, 3, "m-s3-bf16");
    }

    #[test]
    fn elastic_matrix_s2_fp32_dp2() {
        kill_recovery_scheme(S2, Dtype::F32, 2, "m-s2-fp32-d2");
    }

    #[test]
    fn elastic_matrix_s2_fp32_dp4() {
        kill_recovery_scheme(S2, Dtype::F32, 4, "m-s2-fp32-d4");
    }
}
