//! Property-based tests over randomly-generated inputs (in-tree
//! generator: `data::Rng64`; the build is offline so no proptest crate).
//! Each property runs a few hundred random cases and shrinks nothing —
//! failures print the seed/case inline, which is enough to reproduce
//! (everything is deterministic in the case parameters).

use std::sync::Arc;
use std::thread;

use frontier_llm::collectives::{chunk_bounds, Algo, Group, NodeMap, SubGroup};
use frontier_llm::config::{lookup, ParallelConfig, ScheduleKind};
use frontier_llm::data::Rng64;
use frontier_llm::hpo::space::Point;
use frontier_llm::hpo::surrogate::Gp;
use frontier_llm::parallel::RankLayout;
use frontier_llm::perf::PerfModel;
use frontier_llm::precision::{
    dequantize_int8, pack_bf16, quantize_int8, unpack_bf16, Dtype, GradWire, LossScaler,
    INT8_BLOCK,
};
use frontier_llm::schedule;
use frontier_llm::util::json::{escape, Json};

#[test]
fn prop_schedules_always_valid() {
    let mut rng = Rng64::new(101);
    for case in 0..300 {
        let p = 1 + rng.below(12) as u32;
        let m = 1 + rng.below(40) as u32;
        let kind = if rng.below(2) == 0 { ScheduleKind::GPipe } else { ScheduleKind::OneF1B };
        let s = schedule::build(kind, p, m);
        s.validate().unwrap_or_else(|e| panic!("case {case} p={p} m={m} {kind:?}: {e}"));
        // 1F1B in-flight bound: stage i holds at most min(p - i, m) acts
        if kind == ScheduleKind::OneF1B {
            for stage in 0..p {
                let cap = (p - stage).min(m);
                assert!(
                    s.peak_inflight(stage) <= cap,
                    "case {case} p={p} m={m} stage {stage}"
                );
            }
        }
    }
}

#[test]
fn prop_interleaved_schedules_valid() {
    let mut rng = Rng64::new(202);
    for case in 0..200 {
        let p = 1 + rng.below(8) as u32;
        let q = 1 + rng.below(5) as u32;
        let m = p * q; // interleaving requires m % p == 0
        let v = [1u32, 2, 3, 4, 8][rng.below(5) as usize];
        let s = schedule::build(ScheduleKind::Interleaved1F1B { v }, p, m);
        s.validate()
            .unwrap_or_else(|e| panic!("case {case} p={p} m={m} v={v}: {e}"));
        assert_eq!(s.v, v);
        for rank in 0..p {
            let ops = &s.streams[rank as usize];
            assert_eq!(ops.len(), (2 * m * v) as usize, "case {case} rank {rank}");
            // per-chunk fwd/bwd pairing: every chunk runs exactly m
            // forwards and m backwards
            for chunk in 0..v {
                let fwd = ops
                    .iter()
                    .filter(|o| o.is_forward() && o.chunk() == chunk)
                    .count();
                let bwd = ops
                    .iter()
                    .filter(|o| !o.is_forward() && o.chunk() == chunk)
                    .count();
                assert_eq!((fwd, bwd), (m as usize, m as usize), "case {case} chunk {chunk}");
            }
            // in-flight chunk activations never exceed GPipe's
            // all-in-flight m*v bound, nor the warmup-ramp bound
            let peak = s.peak_inflight(rank);
            let ramp = 2 * (p - 1 - rank) + (v - 1) * p + 1;
            assert!(
                peak <= (m * v).min(ramp),
                "case {case} rank {rank}: peak {peak} > min({}, {ramp})",
                m * v
            );
        }
    }
}

#[test]
fn prop_bubble_formula_bounds() {
    let mut rng = Rng64::new(77);
    for _ in 0..200 {
        let p = 1 + rng.below(64) as u32;
        let m = 1 + rng.below(512) as u32;
        let v = 1 + rng.below(4) as u32;
        for kind in [
            ScheduleKind::GPipe,
            ScheduleKind::OneF1B,
            ScheduleKind::Interleaved1F1B { v },
        ] {
            let b = kind.bubble_fraction(p, m);
            assert!((0.0..1.0).contains(&b), "{kind:?} p={p} m={m}: {b}");
            if p == 1 {
                assert!(b == 0.0);
            }
            // more micro-batches never increases the bubble
            let b2 = kind.bubble_fraction(p, m + 8);
            assert!(b2 <= b + 1e-12);
        }
    }
}

#[test]
fn prop_layout_bijection_and_partition() {
    let mut rng = Rng64::new(5);
    for _ in 0..100 {
        let tp = 1 + rng.below(8) as u32;
        let pp = 1 + rng.below(8) as u32;
        let dp = 1 + rng.below(8) as u32;
        let l = RankLayout::new(tp, pp, dp);
        let mut seen = vec![false; l.world_size() as usize];
        for r in 0..l.world_size() {
            assert_eq!(l.rank_of(l.coords(r)), r);
            assert!(!seen[r as usize]);
            seen[r as usize] = true;
        }
        // each group type partitions the world
        for groups in [l.all_tp_groups(), l.all_dp_groups(), l.all_pp_groups()] {
            let total: usize = groups.iter().map(|g| g.len()).sum();
            assert_eq!(total, l.world_size() as usize);
        }
    }
}

#[test]
fn prop_chunk_bounds_partition() {
    let mut rng = Rng64::new(9);
    for _ in 0..300 {
        let len = rng.below(10_000) as usize;
        let n = 1 + rng.below(16) as usize;
        let b = chunk_bounds(len, n);
        assert_eq!(b.len(), n);
        assert_eq!(b[0].0, 0);
        assert_eq!(b[n - 1].1, len);
        for w in b.windows(2) {
            assert_eq!(w[0].1, w[1].0);
            // sizes differ by at most one, earlier chunks bigger
            let s0 = w[0].1 - w[0].0;
            let s1 = w[1].1 - w[1].0;
            assert!(s0 == s1 || s0 == s1 + 1);
        }
        // partition: sizes sum to len; cover: exactly len % n chunks carry
        // the +1 remainder, and every size is base or base + 1
        let total: usize = b.iter().map(|&(lo, hi)| hi - lo).sum();
        assert_eq!(total, len);
        let base = len / n;
        let big = b.iter().filter(|&&(lo, hi)| hi - lo == base + 1).count();
        assert_eq!(big, len % n);
        assert!(b.iter().all(|&(lo, hi)| hi - lo == base || hi - lo == base + 1));
    }
}

#[test]
fn prop_allreduce_equals_reduce_scatter_allgather() {
    // all_reduce_sum ≡ reduce_scatter_sum + all_gather, for BOTH Algo
    // variants and every group size 2–8 (the ZeRO-1 <-> DDP wire-volume
    // equivalence the paper leans on in §II.D)
    let mut rng = Rng64::new(411);
    for n in 2..=8usize {
        for algo in [Algo::Naive, Algo::Ring] {
            let len = n + rng.below(200) as usize;
            let seed = rng.next_u64();
            let group = Group::new(n);
            let handles: Vec<_> = (0..n)
                .map(|rank| {
                    let g = group.clone();
                    thread::spawn(move || {
                        let mut local = Rng64::new(seed ^ (rank as u64 + 1) * 0x9E37);
                        let data: Vec<f32> =
                            (0..len).map(|_| local.normal() as f32).collect();
                        let mut ar = data.clone();
                        g.all_reduce_sum(rank, &mut ar, algo);
                        let shard = g.reduce_scatter_sum(rank, &data);
                        let mut rsag = vec![0.0f32; len];
                        g.all_gather(rank, &shard, &mut rsag);
                        (ar, rsag)
                    })
                })
                .collect();
            for (rank, h) in handles.into_iter().enumerate() {
                let (ar, rsag) = h.join().unwrap();
                for (i, (a, b)) in ar.iter().zip(&rsag).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "n={n} {algo:?} rank={rank} i={i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_subgroup_allreduce_independence() {
    // split a parent world into two disjoint subgroups; each must reduce
    // exactly its members' data, concurrently, for random splits and
    // payload lengths — and match a directly-computed per-subgroup sum
    let mut rng = Rng64::new(733);
    for case in 0..10 {
        let n = 4 + rng.below(5) as usize; // 4..8
        let split = 1 + rng.below(n as u64 - 1) as usize; // 1..n-1
        let len = 1 + rng.below(120) as usize;
        let rounds = 1 + rng.below(4) as usize;
        let seed = rng.next_u64();
        let world = Group::new(n);
        let a = SubGroup::new(&world, (0..split).collect(), 0);
        let b = SubGroup::new(&world, (split..n).collect(), 1);
        let data = move |rank: usize, round: usize, i: usize| -> f32 {
            let mut r = Rng64::new(seed ^ ((rank * 31 + round * 7 + i) as u64 + 1));
            r.normal() as f32
        };
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let sub = if rank < split { a.clone() } else { b.clone() };
                thread::spawn(move || {
                    let mut out = Vec::new();
                    for round in 0..rounds {
                        let mut buf: Vec<f32> =
                            (0..len).map(|i| data(rank, round, i)).collect();
                        sub.all_reduce_sum(rank, &mut buf);
                        out.push(buf);
                    }
                    out
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for rank in 0..n {
            let members: Vec<usize> =
                if rank < split { (0..split).collect() } else { (split..n).collect() };
            for round in 0..rounds {
                for i in 0..len {
                    let want: f32 = members.iter().map(|&m| data(m, round, i)).sum();
                    let got = results[rank][round][i];
                    assert!(
                        (got - want).abs() < 1e-3,
                        "case {case} rank {rank} round {round} i {i}: {got} vs {want}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_ring_allreduce_matches_naive() {
    let mut rng = Rng64::new(31);
    for case in 0..12 {
        let n = 2 + rng.below(6) as usize;
        let len = 1 + rng.below(500) as usize;
        let seed = rng.next_u64();
        let group = Group::new(n);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let g = group.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ rank as u64);
                    let data: Vec<f32> =
                        (0..len).map(|_| local.normal() as f32).collect();
                    let mut ring = data.clone();
                    g.all_reduce_sum(rank, &mut ring, Algo::Ring);
                    let mut naive = data;
                    g.all_reduce_sum(rank, &mut naive, Algo::Naive);
                    (ring, naive)
                })
            })
            .collect();
        let results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        for (rank, (ring, naive)) in results.iter().enumerate() {
            for i in 0..len {
                assert!(
                    (ring[i] - naive[i]).abs() < 1e-3,
                    "case {case} rank {rank} i={i}: {} vs {}",
                    ring[i],
                    naive[i]
                );
            }
        }
        // all ranks agree with each other
        for r in 1..n {
            assert_eq!(results[0].0.len(), results[r].0.len());
        }
    }
}

#[test]
fn prop_bucketed_nonblocking_allreduce_matches_blocking() {
    // the engine's overlapped grad-sync primitive: splitting a buffer
    // into 1–4 in-flight nonblocking buckets must equal the blocking
    // naive all-reduce BITWISE (both reduce in rank order), for random
    // group sizes, lengths and bucket counts
    let mut rng = Rng64::new(909);
    for case in 0..12u64 {
        let n = 1 + rng.below(4) as usize; // 1..4 ranks
        let len = 4 + rng.below(300) as usize;
        let n_buckets = 1 + rng.below(4) as usize; // 1..4 in flight
        let seed = rng.next_u64();
        let group = Group::new(n);
        let bounds = chunk_bounds(len, n_buckets);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let g = group.clone();
                let bounds = bounds.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64 + 7) * 0x51);
                    let data: Vec<f32> = (0..len).map(|_| local.normal() as f32).collect();
                    let mut want = data.clone();
                    g.all_reduce_sum(rank, &mut want, Algo::Naive);
                    // launch every bucket before waiting on any
                    let started: Vec<_> = bounds
                        .iter()
                        .enumerate()
                        .map(|(idx, &(lo, hi))| {
                            let tag = (case << 8) | idx as u64;
                            (lo, hi, g.start_all_reduce(rank, tag, data[lo..hi].to_vec()))
                        })
                        .collect();
                    let mut got = vec![0.0f32; len];
                    for (lo, hi, h) in started {
                        got[lo..hi].copy_from_slice(&h.wait());
                    }
                    (want, got)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (want, got) = h.join().unwrap();
            assert_eq!(want, got, "case {case} rank {rank}: bucketed != blocking");
        }
    }
}

#[test]
fn prop_reduce_scatter_allgather_roundtrip() {
    let mut rng = Rng64::new(57);
    for _ in 0..8 {
        let n = 1 + rng.below(5) as usize;
        let len = n + rng.below(300) as usize;
        let seed = rng.next_u64();
        let group = Group::new(n);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let g: Arc<Group> = group.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64) << 3);
                    let data: Vec<f32> = (0..len).map(|_| local.normal() as f32).collect();
                    let mut want = data.clone();
                    g.all_reduce_sum(rank, &mut want, Algo::Naive);
                    let shard = g.reduce_scatter_sum(rank, &data);
                    let mut got = vec![0.0; len];
                    g.all_gather(rank, &shard, &mut got);
                    (want, got)
                })
            })
            .collect();
        for h in handles {
            let (want, got) = h.join().unwrap();
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-4);
            }
        }
    }
}

#[test]
fn prop_perf_model_total_is_sum_of_parts() {
    let mut rng = Rng64::new(13);
    let perf = PerfModel::default();
    let model = lookup("22b").unwrap();
    let mut evaluated = 0;
    for _ in 0..200 {
        let tp = [1u32, 2, 4, 8][rng.below(4) as usize];
        let pp = [1u32, 2, 4, 8][rng.below(4) as usize];
        let dp = 1 + rng.below(4) as u32;
        let mbs = 1 + rng.below(4) as u32;
        let m = 1 + rng.below(16) as u32;
        let cfg = ParallelConfig::default()
            .with_tp(tp)
            .with_pp(pp)
            .with_dp(dp)
            .with_mbs(mbs)
            .with_gbs(dp * mbs * m);
        if let Ok(b) = perf.evaluate(&model, &cfg) {
            evaluated += 1;
            let parts = b.t_compute + b.t_tp_comm + b.t_bubble + b.t_pp_comm + b.t_dp_comm
                + b.t_optimizer;
            let rel = (b.t_step - parts).abs() / b.t_step;
            assert!(rel < 1e-6, "decomposition must be exact: {rel}");
            assert!(b.pct_peak > 0.0 && b.pct_peak < 100.0);
            assert!(b.hw_flops_per_gpu >= b.model_flops_per_gpu);
        }
    }
    assert!(evaluated > 50, "too few feasible samples: {evaluated}");
}

#[test]
fn prop_hpo_points_round_trip_configs() {
    let mut rng = Rng64::new(21);
    for _ in 0..300 {
        let p = Point::sample(&mut rng);
        if let Ok((model, cfg)) = p.to_config() {
            cfg.validate().expect("instantiated config must validate");
            assert_eq!(cfg.world_size(), p.gpus());
            assert_eq!(cfg.microbatches(), p.gas);
            assert_eq!(model.name, "175b");
        }
    }
}

#[test]
fn prop_gp_predictions_finite() {
    let mut rng = Rng64::new(99);
    for _ in 0..20 {
        let n = 3 + rng.below(30) as usize;
        let d = 1 + rng.below(6) as usize;
        let x: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.next_f64()).collect()).collect();
        let y: Vec<f64> = (0..n).map(|_| rng.normal() * 10.0).collect();
        let gp = Gp::fit(&x, &y);
        for _ in 0..10 {
            let q: Vec<f64> = (0..d).map(|_| rng.next_f64() * 2.0 - 0.5).collect();
            let (mu, sigma) = gp.predict(&q);
            assert!(mu.is_finite() && sigma.is_finite() && sigma >= 0.0);
            let ei = gp.expected_improvement(&q, 0.0);
            assert!(ei.is_finite() && ei >= 0.0);
        }
    }
}

#[test]
fn prop_json_escape_round_trip() {
    let mut rng = Rng64::new(7);
    for _ in 0..200 {
        let len = rng.below(40) as usize;
        let s: String = (0..len)
            .map(|_| {
                let c = rng.below(128) as u8;
                if c.is_ascii_graphic() || c == b' ' {
                    c as char
                } else {
                    '\n'
                }
            })
            .collect();
        let parsed = Json::parse(&escape(&s)).unwrap();
        assert_eq!(parsed.as_str().unwrap(), s);
    }
}

#[test]
fn prop_bf16_quantize_round_trip_idempotent_monotone() {
    // random magnitudes across the whole exponent range: quantization is
    // idempotent, monotone, sign-preserving, and pack/unpack is bit-exact
    let mut rng = Rng64::new(4242);
    for case in 0..50 {
        let len = 1 + rng.below(97) as usize; // odd lengths exercise the pad
        let xs: Vec<f32> = (0..len)
            .map(|i| {
                let mag = 10.0f64.powi((i % 21) as i32 - 10);
                (rng.normal() * mag) as f32
            })
            .collect();
        let q = Dtype::Bf16.quantized(&xs);
        for (i, (&x, &qx)) in xs.iter().zip(&q).enumerate() {
            assert_eq!(
                Dtype::Bf16.quantize(qx).to_bits(),
                qx.to_bits(),
                "case {case} i {i}: idempotence"
            );
            assert_eq!(qx.signum(), x.signum(), "case {case} i {i}: sign");
        }
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let qs: Vec<f32> = sorted.iter().map(|&v| Dtype::Bf16.quantize(v)).collect();
        for (i, w) in qs.windows(2).enumerate() {
            assert!(w[0] <= w[1], "case {case} i {i}: monotonicity");
        }
        let back = unpack_bf16(&pack_bf16(&xs), len);
        for (i, (a, b)) in back.iter().zip(&q).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "case {case} i {i}: pack round trip");
        }
    }
}

#[test]
fn prop_packed_bucket_allreduce_equals_f32_allreduce_of_quantized() {
    // THE packed-wire contract: a bf16 nonblocking all-reduce is bitwise
    // the blocking Naive f32 all-reduce of the quantized inputs (both
    // reduce in rank order), for random group sizes / lengths / bucket
    // splits — so halving the wire cannot perturb the trajectory beyond
    // the input quantization itself
    let mut rng = Rng64::new(616);
    for case in 0..12u64 {
        let n = 1 + rng.below(4) as usize;
        let len = 1 + rng.below(301) as usize;
        let n_buckets = 1 + rng.below(4) as usize;
        let seed = rng.next_u64();
        let group = Group::new(n);
        let bounds = chunk_bounds(len, n_buckets);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let g = group.clone();
                let bounds = bounds.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64 + 3) * 0x77);
                    let data: Vec<f32> = (0..len).map(|_| local.normal() as f32).collect();
                    let mut want = Dtype::Bf16.quantized(&data);
                    g.all_reduce_sum(rank, &mut want, Algo::Naive);
                    let started: Vec<_> = bounds
                        .iter()
                        .enumerate()
                        .map(|(idx, &(lo, hi))| {
                            let tag = (case << 8) | idx as u64;
                            (
                                lo,
                                hi,
                                g.start_all_reduce_dtype(
                                    rank,
                                    tag,
                                    data[lo..hi].to_vec(),
                                    Dtype::Bf16,
                                ),
                            )
                        })
                        .collect();
                    let mut got = vec![0.0f32; len];
                    for (lo, hi, h) in started {
                        got[lo..hi].copy_from_slice(&h.wait());
                    }
                    (want, got)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (want, got) = h.join().unwrap();
            assert_eq!(want, got, "case {case} rank {rank}: packed != quantized f32");
        }
    }
}

#[test]
fn prop_packed_subgroup_allreduce_equals_quantized_rank_order_sum() {
    // same contract for the TP subgroup exchange, over threads
    let mut rng = Rng64::new(929);
    for case in 0..8 {
        let tp = 2 + rng.below(3) as usize; // 2..4
        let len = 1 + rng.below(120) as usize;
        let seed = rng.next_u64();
        let world = Group::new(tp);
        let sub = SubGroup::new(&world, (0..tp).collect(), 0);
        let data = move |rank: usize, i: usize| -> f32 {
            let mut r = Rng64::new(seed ^ ((rank * 131 + i) as u64 + 1));
            r.normal() as f32
        };
        let handles: Vec<_> = (0..tp)
            .map(|rank| {
                let s = sub.clone();
                thread::spawn(move || {
                    let mut buf: Vec<f32> = (0..len).map(|i| data(rank, i)).collect();
                    s.all_reduce_sum_cfg(rank, &mut buf, Algo::Ring, Dtype::Bf16);
                    buf
                })
            })
            .collect();
        let mut want = vec![0.0f32; len];
        for r in 0..tp {
            for (i, w) in want.iter_mut().enumerate() {
                *w += Dtype::Bf16.quantize(data(r, i));
            }
        }
        for (rank, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            for i in 0..len {
                assert_eq!(
                    got[i].to_bits(),
                    want[i].to_bits(),
                    "case {case} tp {tp} rank {rank} i {i}"
                );
            }
        }
    }
}

/// Random node assignment for `n` ranks over at most `max_nodes` nodes
/// (dense renumbering happens inside [`NodeMap::new`]).
fn random_nodes(rng: &mut Rng64, n: usize, max_nodes: usize) -> Vec<usize> {
    (0..n).map(|_| rng.below(max_nodes as u64) as usize).collect()
}

#[test]
fn prop_hier_allreduce_matches_flat_bitwise() {
    // THE hierarchical invariant: for a value-preserving inter-node wire
    // (fp32 over fp32 storage, bf16 over bf16 storage) the two-tier fold
    // collapses to exactly the flat rank-order sum — BITWISE, across
    // every group size 2–8, node count 1–4 and random placement
    let mut rng = Rng64::new(1201);
    for case in 0..24u64 {
        let n = 2 + rng.below(7) as usize; // 2..8
        let nodes = 1 + rng.below(4) as usize; // 1..4
        let len = 1 + rng.below(300) as usize;
        let wire = if rng.below(2) == 0 { Dtype::F32 } else { Dtype::Bf16 };
        let assignment = random_nodes(&mut rng, n, nodes);
        let seed = rng.next_u64();
        let flat = Group::new(n);
        let hier = Group::new_with_nodes(n, Some(NodeMap::new(&assignment)));
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let f = flat.clone();
                let h = hier.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64 + 11) * 0xA5);
                    let data: Vec<f32> = (0..len).map(|_| local.normal() as f32).collect();
                    let want = f.start_all_reduce_dtype(rank, case, data.clone(), wire).wait();
                    let got = h
                        .start_all_reduce_hier(rank, case, data, wire, GradWire::for_dtype(wire))
                        .wait();
                    (want, got)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (want, got) = h.join().unwrap();
            for i in 0..len {
                assert_eq!(
                    want[i].to_bits(),
                    got[i].to_bits(),
                    "case {case} n={n} nodes={nodes} {wire:?} {assignment:?} rank {rank} i {i}"
                );
            }
        }
    }
}

#[test]
fn prop_hier_reduce_scatter_matches_flat_bitwise() {
    // same invariant for the ZeRO-2/3 gradient dataflow: the owner's
    // redeemed shard under the two-tier round is bit-for-bit the flat
    // partition-aligned reduce-scatter's, for random owners/placements
    let mut rng = Rng64::new(1307);
    for case in 0..24u64 {
        let n = 2 + rng.below(7) as usize;
        let nodes = 1 + rng.below(4) as usize;
        let len = 1 + rng.below(300) as usize;
        let owner = rng.below(n as u64) as usize;
        let wire = if rng.below(2) == 0 { Dtype::F32 } else { Dtype::Bf16 };
        let assignment = random_nodes(&mut rng, n, nodes);
        let seed = rng.next_u64();
        let flat = Group::new(n);
        let hier = Group::new_with_nodes(n, Some(NodeMap::new(&assignment)));
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let f = flat.clone();
                let h = hier.clone();
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64 + 5) * 0xC3);
                    let data: Vec<f32> = (0..len).map(|_| local.normal() as f32).collect();
                    let want =
                        f.start_reduce_scatter_dtype(rank, case, data.clone(), owner, wire).wait();
                    let got = h
                        .start_reduce_scatter_hier(
                            rank,
                            case,
                            data,
                            owner,
                            wire,
                            GradWire::for_dtype(wire),
                        )
                        .wait();
                    (want, got)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (want, got) = h.join().unwrap();
            assert_eq!(
                want.is_some(),
                rank == owner,
                "case {case}: only the owner materialises a shard"
            );
            assert_eq!(got.is_some(), rank == owner);
            if let (Some(w), Some(g)) = (want, got) {
                for i in 0..len {
                    assert_eq!(
                        w[i].to_bits(),
                        g[i].to_bits(),
                        "case {case} n={n} nodes={nodes} owner={owner} {assignment:?} i {i}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_hier_allgather_matches_flat_bitwise() {
    // gather assembly is pure placement, so hier ≡ flat bitwise always —
    // including under bf16 wire-casting of the shards
    let mut rng = Rng64::new(1409);
    for case in 0..16u64 {
        let n = 2 + rng.below(7) as usize;
        let nodes = 1 + rng.below(4) as usize;
        let total = n + rng.below(300) as usize;
        let wire = if rng.below(2) == 0 { Dtype::F32 } else { Dtype::Bf16 };
        let assignment = random_nodes(&mut rng, n, nodes);
        let seed = rng.next_u64();
        let flat = Group::new(n);
        let hier = Group::new_with_nodes(n, Some(NodeMap::new(&assignment)));
        let bounds = chunk_bounds(total, n);
        let handles: Vec<_> = (0..n)
            .map(|rank| {
                let f = flat.clone();
                let h = hier.clone();
                let (lo, hi) = bounds[rank];
                thread::spawn(move || {
                    let mut local = Rng64::new(seed ^ (rank as u64 + 9) * 0xE1);
                    let shard: Arc<Vec<f32>> =
                        Arc::new((lo..hi).map(|_| local.normal() as f32).collect());
                    let want =
                        f.start_all_gather_shared(rank, case, shard.clone(), total, wire).wait();
                    let got = h.start_all_gather_hier(rank, case, shard, total, wire).wait();
                    (want, got)
                })
            })
            .collect();
        for (rank, h) in handles.into_iter().enumerate() {
            let (want, got) = h.join().unwrap();
            for i in 0..total {
                assert_eq!(
                    want[i].to_bits(),
                    got[i].to_bits(),
                    "case {case} n={n} nodes={nodes} {wire:?} rank {rank} i {i}"
                );
            }
        }
    }
}

#[test]
fn prop_hier_int8_wire_deterministic_and_bounded() {
    // the int8 inter-node wire always re-quantizes, so hier ≠ flat — but
    // the fold must be (a) identical across repeated trials regardless of
    // deposit arrival order (rank-order determinism), and (b) within the
    // blockwise quantization error of the flat sum: each node partial
    // round-trips through one int8 encoding, so the node-order fold of k
    // partials drifts at most k × (block max-abs / 254) per lane
    let mut rng = Rng64::new(1511);
    for case in 0..10u64 {
        let n = 2 + rng.below(7) as usize;
        let nodes = 2 + rng.below(3) as usize; // ≥ 2: force the inter hop
        let len = 1 + rng.below(400) as usize;
        let assignment = random_nodes(&mut rng, n, nodes);
        let seed = rng.next_u64();
        let trial = |reversed: bool, tag: u64| -> Vec<Vec<f32>> {
            let hier = Group::new_with_nodes(n, Some(NodeMap::new(&assignment)));
            let order: Vec<usize> =
                if reversed { (0..n).rev().collect() } else { (0..n).collect() };
            let handles: Vec<_> = order
                .into_iter()
                .map(|rank| {
                    let h = hier.clone();
                    thread::spawn(move || {
                        let mut local = Rng64::new(seed ^ (rank as u64 + 13) * 0xF7);
                        let data: Vec<f32> =
                            (0..len).map(|_| local.normal() as f32).collect();
                        let out = h
                            .start_all_reduce_hier(rank, tag, data, Dtype::F32, GradWire::Int8)
                            .wait();
                        (rank, out)
                    })
                })
                .collect();
            let mut by_rank = vec![Vec::new(); n];
            for h in handles {
                let (rank, out) = h.join().unwrap();
                by_rank[rank] = out;
            }
            by_rank
        };
        let a = trial(false, case);
        let b = trial(true, case); // reversed spawn order: different arrivals
        for rank in 0..n {
            for i in 0..len {
                assert_eq!(
                    a[rank][i].to_bits(),
                    b[rank][i].to_bits(),
                    "case {case} rank {rank} i {i}: int8 fold must not depend on arrival order"
                );
            }
        }
        // error bound vs the flat rank-order f32 sum
        let mut flat_sum = vec![0.0f32; len];
        let mut node_max = vec![vec![0.0f32; len.div_ceil(INT8_BLOCK)]; nodes];
        for rank in 0..n {
            let mut local = Rng64::new(seed ^ (rank as u64 + 13) * 0xF7);
            for i in 0..len {
                let x = local.normal() as f32;
                flat_sum[i] += x;
                let m = &mut node_max[assignment[rank]][i / INT8_BLOCK];
                // per-node partials are ≤ sum of member |x| blockwise
                *m += x.abs();
            }
        }
        for i in 0..len {
            let bound: f32 =
                (0..nodes).map(|nd| node_max[nd][i / INT8_BLOCK] / 253.0).sum();
            assert!(
                (a[0][i] - flat_sum[i]).abs() <= bound,
                "case {case} i {i}: {} vs {} exceeds the blockwise bound {bound}",
                a[0][i],
                flat_sum[i]
            );
        }
    }
}

#[test]
fn prop_int8_blockwise_roundtrip_error_bound() {
    // per 128-float block with scale = max|x| / 127 and RNE codes, the
    // round-trip error is at most scale / 2 = max|x| / 254 per element
    // (≤ /253 here for float slack), the encoding is deterministic, and
    // zero blocks survive exactly
    let mut rng = Rng64::new(1613);
    for case in 0..100 {
        let len = 1 + rng.below(1000) as usize;
        let xs: Vec<f32> = (0..len)
            .map(|i| {
                let mag = 10.0f64.powi((i % 13) as i32 - 6);
                (rng.normal() * mag) as f32
            })
            .collect();
        let (scales, codes) = quantize_int8(&xs);
        assert_eq!(scales.len(), len.div_ceil(INT8_BLOCK), "case {case}: one scale per block");
        assert_eq!(codes.len(), len);
        let (s2, c2) = quantize_int8(&xs);
        assert_eq!(scales, s2, "case {case}: deterministic scales");
        assert_eq!(codes, c2, "case {case}: deterministic codes");
        let back = dequantize_int8(&scales, &codes);
        assert_eq!(back.len(), len);
        for (b, block) in xs.chunks(INT8_BLOCK).enumerate() {
            let max_abs = block.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
            for (j, &x) in block.iter().enumerate() {
                let xhat = back[b * INT8_BLOCK + j];
                if max_abs == 0.0 {
                    assert_eq!(xhat, 0.0, "case {case} block {b}: zero block");
                } else {
                    assert!(
                        (x - xhat).abs() <= max_abs / 253.0,
                        "case {case} block {b} j {j}: |{x} - {xhat}| > {max_abs}/253"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_loss_scaler_state_machine() {
    // random overflow sequences: the scale is always init × 2^k with the
    // exponent fully determined by the (overflow, growth) history, skips
    // are counted exactly, and the floor/ceiling hold
    let mut rng = Rng64::new(303);
    for case in 0..50 {
        let interval = rng.below(5) as u32; // 0 disables growth
        let mut s = LossScaler::new(256.0, interval);
        let mut scale = 256.0f32;
        let mut good = 0u32;
        let mut skips = 0u64;
        for step in 0..200 {
            let overflow = rng.below(4) == 0;
            let skipped = s.update(overflow);
            assert_eq!(skipped, overflow, "case {case} step {step}");
            if overflow {
                scale = (scale * 0.5).max(LossScaler::MIN_SCALE);
                good = 0;
                skips += 1;
            } else {
                good += 1;
                if interval > 0 && good >= interval {
                    scale = (scale * 2.0).min(LossScaler::MAX_SCALE);
                    good = 0;
                }
            }
            assert_eq!(s.scale(), scale, "case {case} step {step}");
            assert_eq!(s.good_steps(), good);
            assert!(s.scale() >= LossScaler::MIN_SCALE && s.scale() <= LossScaler::MAX_SCALE);
        }
        assert_eq!(s.steps_skipped(), skips);
    }
}

#[test]
fn prop_memory_monotone_in_sharding() {
    // more TP or PP never increases the per-GPU footprint
    let mut rng = Rng64::new(3);
    let model = lookup("175b").unwrap();
    for _ in 0..100 {
        let tp = [1u32, 2, 4][rng.below(3) as usize];
        let pp = [1u32, 2, 4, 8][rng.below(4) as usize];
        let cfg = ParallelConfig::default().with_tp(tp).with_pp(pp).with_gbs(8);
        let more_tp = cfg.clone().with_tp(tp * 2);
        let more_pp = cfg.clone().with_pp(pp * 2);
        let base = frontier_llm::mem::per_gpu(&model, &cfg).total();
        assert!(frontier_llm::mem::per_gpu(&model, &more_tp).total() <= base);
        assert!(frontier_llm::mem::per_gpu(&model, &more_pp).total() <= base);
    }
}
