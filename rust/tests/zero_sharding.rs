//! Engine-level tests of the staged sharded-DP subsystem (ZeRO-2/3):
//! true reduce-scatter gradient dataflow, on-demand parameter gathering,
//! packed p2p activations, and the RS/AG wire contracts.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **Trajectory equivalence** — 20-step loss AND grad-norm
//!   trajectories of stages 2 and 3 equal stage 0 (DDP) **bitwise** at
//!   fp32, at dp ∈ {2, 4} × tp ∈ {1, 2} × pp ∈ {1, 2}; under bf16 the
//!   stages stay bitwise-equal to bf16 DDP (same rank-order reductions,
//!   lossless packed gathers) and track fp32 within the PR-4 tolerance.
//! * **RS/AG wire, pinned EXACTLY** — the reduce-scatter bucket payload
//!   equals the stage-0 all-reduce payload (`params × dtype` per step:
//!   sharding changes residency, not volume); the stage-1/2 updated-
//!   parameter all-gather and ZeRO-3's per-use gathers are pinned
//!   against the analytic `perf` terms; bf16 is exactly half of fp32
//!   everywhere.
//! * **Packed p2p** — boundary activations ride the wire dtype; the
//!   measured `pp_p2p_payload_bytes` is pinned EXACTLY against the
//!   analytic PP p2p term and halves under bf16 without moving the
//!   trajectory (grid values pack losslessly).
//! * **Checkpoint resume** — stage-N save → stage-N resume continues
//!   the straight run; the layout-identical 1 ↔ 2 pair cross-resumes;
//!   stage mismatches touching 0 or 3 are rejected with a clear error.
//! * **Residency** — ZeRO-3's measured gather high-water mark stays
//!   within the 2-layer gather-use-drop bound, far below the worker's
//!   model share.

use std::path::PathBuf;

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::perf::{
    builtin_pp_p2p_floats_per_step, builtin_zero3_ag_floats_per_step, dp_grad_payload_bytes,
    zero1_allgather_payload_bytes,
};
use frontier_llm::precision::Dtype;
use frontier_llm::runtime::BuiltinSpec;
use frontier_llm::zero::ShardingStage;

const S0: ShardingStage = ShardingStage::Ddp;
const S1: ShardingStage = ShardingStage::OptimizerStates;
const S2: ShardingStage = ShardingStage::Gradients;
const S3: ShardingStage = ShardingStage::Parameters;

#[allow(clippy::too_many_arguments)]
fn cfg(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    sched: ScheduleKind,
    precision: Dtype,
) -> EngineConfig {
    EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        schedule: sched,
        microbatches: m,
        steps,
        zero_stage: stage,
        precision,
        // small buckets so every stage splits into many RS/AR rounds
        grad_bucket_floats: 128,
        seed: 42,
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    sched: ScheduleKind,
    precision: Dtype,
) -> TrainReport {
    train(&cfg(bundle, tp, dp, m, steps, stage, sched, precision))
        .expect("training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn grad_norms(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.grad_norm).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

// =========================================================================
// THE acceptance grid: stages 2/3 ≡ DDP bitwise at fp32,
// dp ∈ {2, 4} × tp ∈ {1, 2} × pp ∈ {1, 2}, 20 steps
// =========================================================================

#[test]
fn stages_match_ddp_bitwise_fp32_20_steps_grid() {
    // pp = 2 runs the 2-stage bundle as a real pipeline; pp = 1 folds it
    // onto one worker via v = 2 chunking — both shapes per (dp, tp)
    let shapes: &[(ScheduleKind, &str)] = &[
        (ScheduleKind::OneF1B, "pp2"),
        (ScheduleKind::Interleaved1F1B { v: 2 }, "pp1(v2)"),
    ];
    for &dp in &[2usize, 4] {
        for &tp in &[1usize, 2] {
            for &(sched, pshape) in shapes {
                let ddp = run("builtin:tiny-s2-mb2", tp, dp, 2, 20, S0, sched, Dtype::F32);
                for stage in [S1, S2, S3] {
                    let z =
                        run("builtin:tiny-s2-mb2", tp, dp, 2, 20, stage, sched, Dtype::F32);
                    let label = format!("dp{dp} tp{tp} {pshape} stage {stage}");
                    assert_eq!(losses(&ddp), losses(&z), "{label}: losses must be bitwise");
                    assert_eq!(
                        grad_norms(&ddp),
                        grad_norms(&z),
                        "{label}: grad norms must be bitwise"
                    );
                }
            }
        }
    }
}

#[test]
fn bf16_stages_match_bf16_ddp_bitwise_and_track_fp32() {
    // the rank-order reductions and lossless packed gathers keep the
    // whole ladder bitwise-equal at bf16 too; fp32 is tracked within the
    // PR-4 tolerance (0.08 over 20 steps)
    for &tp in &[1usize, 2] {
        let fp32 = run("builtin:tiny-s2-mb2", tp, 2, 2, 20, S0, ScheduleKind::OneF1B, Dtype::F32);
        let ddp = run("builtin:tiny-s2-mb2", tp, 2, 2, 20, S0, ScheduleKind::OneF1B, Dtype::Bf16);
        for stage in [S2, S3] {
            let z =
                run("builtin:tiny-s2-mb2", tp, 2, 2, 20, stage, ScheduleKind::OneF1B, Dtype::Bf16);
            assert_eq!(
                losses(&ddp),
                losses(&z),
                "tp{tp} stage {stage}: bf16 ladder must stay bitwise"
            );
            assert_close(&losses(&fp32), &losses(&z), 0.08, &format!("tp{tp} {stage} vs fp32"));
            assert_eq!(z.steps_skipped, 0);
        }
    }
}

#[test]
fn stage3_overlapped_equals_sequential_bitwise() {
    // the PR-3 overlap invariant survives the RS + on-demand-gather
    // dataflow: deposits reduce in rank order whenever they land
    for stage in [S2, S3] {
        let mk = |overlap: bool| {
            let mut c = cfg(
                "builtin:tiny-s4-mb2",
                1,
                2,
                4,
                10,
                stage,
                ScheduleKind::Interleaved1F1B { v: 2 },
                Dtype::F32,
            );
            c.overlap_grad_sync = overlap;
            train(&c).expect("training must succeed")
        };
        let overlapped = mk(true);
        let sequential = mk(false);
        assert_eq!(
            losses(&overlapped),
            losses(&sequential),
            "stage {stage}: overlapped ≡ sequential must be bitwise"
        );
        assert_eq!(grad_norms(&overlapped), grad_norms(&sequential));
    }
}

#[test]
fn stage3_loss_descends_and_is_deterministic() {
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 2, 4, 8, S3, ScheduleKind::OneF1B, Dtype::F32);
    c.adam.lr = 2e-2;
    let a = train(&c).unwrap();
    let b = train(&c).unwrap();
    assert_eq!(losses(&a), losses(&b), "stage-3 engine must be deterministic");
    assert!(
        a.final_loss() < a.initial_loss(),
        "stage-3 training must learn: {:?}",
        losses(&a)
    );
    assert!(a.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
}

// =========================================================================
// RS/AG wire contracts, pinned EXACTLY against the perf terms
// =========================================================================

#[test]
fn stage2_rs_payload_equals_ddp_reduce_volume() {
    // sharding the reduced gradient changes who materialises it, not the
    // wire volume: the partition-aligned RS buckets move exactly the
    // stage-0 payload, and the updated-parameter AG matches stage 1's
    let spec = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
    let total = spec.total_params() as u64;
    let steps = 4u32;
    for dp in [2usize, 4] {
        for (precision, width) in [(Dtype::F32, 4u64), (Dtype::Bf16, 2u64)] {
            let r = run(
                "builtin:tiny-s2-mb2",
                1,
                dp,
                2,
                steps,
                S2,
                ScheduleKind::OneF1B,
                precision,
            );
            assert_eq!(
                r.dp_bucket_payload_bytes,
                steps as u64 * dp_grad_payload_bytes(total, width),
                "dp={dp} {}: RS reduce half",
                precision.name()
            );
            assert_eq!(
                r.dp_param_ag_bytes,
                steps as u64 * zero1_allgather_payload_bytes(total, width),
                "dp={dp} {}: updated-param AG half",
                precision.name()
            );
        }
    }
}

#[test]
fn stage3_ag_payload_matches_on_demand_gather_term() {
    // ZeRO-3 gathers per USE, not per step: the analytic per-use term,
    // summed over global stages, pins the measured AG payload exactly —
    // and bf16 packs it to exactly half
    let spec = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
    let stage_params: Vec<u64> =
        (0..spec.n_stages).map(|g| spec.stage_params(g) as u64).collect();
    let (m, steps) = (2u32, 4u32);
    let floats = builtin_zero3_ag_floats_per_step(&stage_params, m as u64);
    for dp in [2usize, 4] {
        let fp32 =
            run("builtin:tiny-s2-mb2", 1, dp, m, steps, S3, ScheduleKind::OneF1B, Dtype::F32);
        let bf16 =
            run("builtin:tiny-s2-mb2", 1, dp, m, steps, S3, ScheduleKind::OneF1B, Dtype::Bf16);
        assert_eq!(
            fp32.dp_param_ag_bytes,
            steps as u64 * 4 * floats,
            "dp={dp}: fp32 on-demand AG pin"
        );
        assert_eq!(
            bf16.dp_param_ag_bytes,
            steps as u64 * 2 * floats,
            "dp={dp}: bf16 on-demand AG pin"
        );
        assert_eq!(2 * bf16.dp_param_ag_bytes, fp32.dp_param_ag_bytes, "exactly half");
        // the gradient reduce half is unchanged from every other stage
        assert_eq!(
            fp32.dp_bucket_payload_bytes,
            steps as u64 * dp_grad_payload_bytes(spec.total_params() as u64, 4),
            "dp={dp}: stage-3 RS volume"
        );
    }
    // the checkpoint save's out-of-band full-param assembly must not
    // advance the on-demand counter — the pin holds with saving enabled
    let dir = resume_dir("z3pin");
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 2, m, steps, S3, ScheduleKind::OneF1B, Dtype::F32);
    c.checkpoint_dir = Some(dir.clone());
    let r = train(&c).unwrap();
    assert_eq!(
        r.dp_param_ag_bytes,
        steps as u64 * 4 * floats,
        "checkpoint gathers must stay uncounted"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stage3_fused_single_stage_gathers_backward_only() {
    // k = 1 folds forward into backward: m gathers per step, not 2m
    let spec = BuiltinSpec::parse("builtin:tiny-s1-mb2").unwrap();
    let stage_params = [spec.stage_params(0) as u64];
    let (m, steps) = (2u32, 3u32);
    let r = run("builtin:tiny-s1-mb2", 1, 2, m, steps, S3, ScheduleKind::OneF1B, Dtype::F32);
    assert_eq!(
        r.dp_param_ag_bytes,
        steps as u64 * 4 * builtin_zero3_ag_floats_per_step(&stage_params, m as u64),
        "fused single-stage AG pin"
    );
}

// =========================================================================
// packed p2p activations, pinned EXACTLY and bitwise-neutral
// =========================================================================

#[test]
fn p2p_payload_pinned_and_halves_under_bf16() {
    // tiny: tokens = mbs × seq = 16, hidden = 16; 2-stage pipeline
    let (tokens, hidden, k) = (16u64, 16u64, 2u64);
    let (m, steps) = (2u32, 3u32);
    let floats = builtin_pp_p2p_floats_per_step(k, 2, m as u64, tokens, hidden);
    for dp in [1usize, 2] {
        let fp32 =
            run("builtin:tiny-s2-mb2", 1, dp, m, steps, S0, ScheduleKind::OneF1B, Dtype::F32);
        let bf16 =
            run("builtin:tiny-s2-mb2", 1, dp, m, steps, S0, ScheduleKind::OneF1B, Dtype::Bf16);
        assert_eq!(
            fp32.pp_p2p_payload_bytes,
            steps as u64 * dp as u64 * 4 * floats,
            "dp={dp}: fp32 p2p pin"
        );
        assert_eq!(
            bf16.pp_p2p_payload_bytes,
            steps as u64 * dp as u64 * 2 * floats,
            "dp={dp}: bf16 p2p pin"
        );
        assert_eq!(2 * bf16.pp_p2p_payload_bytes, fp32.pp_p2p_payload_bytes);
    }
    // v-chunked boundaries still cross whenever pp > 1: s4 at v=2 is a
    // 2-worker pipeline with 3 crossing boundaries
    let r = run(
        "builtin:tiny-s4-mb2",
        1,
        1,
        4,
        2,
        S0,
        ScheduleKind::Interleaved1F1B { v: 2 },
        Dtype::F32,
    );
    let want = 2 * 4 * builtin_pp_p2p_floats_per_step(4, 2, 4, tokens, hidden);
    assert_eq!(r.pp_p2p_payload_bytes, want, "v-chunked p2p pin");
    // pp = 1 moves nothing across the wire
    let r = run(
        "builtin:tiny-s4-mb2",
        1,
        1,
        4,
        2,
        S0,
        ScheduleKind::Interleaved1F1B { v: 4 },
        Dtype::F32,
    );
    assert_eq!(r.pp_p2p_payload_bytes, 0, "single-worker boundaries are local");
}

#[test]
fn packed_p2p_does_not_move_the_bf16_trajectory() {
    // boundary payloads are grid values, so packing is lossless: the
    // multi-worker (packed-wire) run equals the single-worker (local,
    // never-packed) chunking of the same model bitwise
    let piped = run("builtin:tiny-s4-mb2", 1, 1, 4, 10, S0, ScheduleKind::OneF1B, Dtype::Bf16);
    let local = run(
        "builtin:tiny-s4-mb2",
        1,
        1,
        4,
        10,
        S0,
        ScheduleKind::Interleaved1F1B { v: 4 },
        Dtype::Bf16,
    );
    assert_eq!(piped.world_size, 4);
    assert_eq!(local.world_size, 1);
    // cross-shape comparison: schedule order reshuffles fp association,
    // which the bf16 grid can amplify — hence the wider tolerance (the
    // bitwise packing pins live in the same-shape ladder tests above)
    assert_close(&losses(&piped), &losses(&local), 0.02, "packed p2p vs local");
}

// =========================================================================
// checkpoint resume across the stage ladder
// =========================================================================

fn resume_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("fllm-zs-{tag}-{}", std::process::id()))
}

#[test]
fn stage_n_save_resumes_stage_n() {
    // 6 straight steps == 3 + checkpoint + 3, per stage
    for stage in [S1, S2, S3] {
        let dir = resume_dir(&format!("same{}", stage.index()));
        let _ = std::fs::remove_dir_all(&dir);
        let straight = run("builtin:tiny-s2-mb2", 1, 2, 2, 6, stage, ScheduleKind::OneF1B, Dtype::F32);
        let mk = |steps: u32, resume: bool| {
            let mut c =
                cfg("builtin:tiny-s2-mb2", 1, 2, 2, steps, stage, ScheduleKind::OneF1B, Dtype::F32);
            c.checkpoint_dir = Some(dir.clone());
            c.resume = resume;
            c
        };
        let first = train(&mk(3, false)).unwrap();
        let second = train(&mk(3, true)).unwrap();
        assert_eq!(second.logs[0].step, 3);
        let mut combined = losses(&first);
        combined.extend(losses(&second));
        assert_close(
            &losses(&straight),
            &combined,
            1e-4,
            &format!("stage {stage} resume vs straight"),
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn stage1_and_stage2_cross_resume() {
    // the 1 <-> 2 pair shares the on-disk layout (full params, 1/dp
    // optimizer shards), so a stage-1 checkpoint resumes as stage 2 and
    // continues the (bitwise-shared) trajectory
    let dir = resume_dir("cross12");
    let _ = std::fs::remove_dir_all(&dir);
    let straight = run("builtin:tiny-s2-mb2", 1, 2, 2, 6, S2, ScheduleKind::OneF1B, Dtype::F32);
    let mk = |steps: u32, stage: ShardingStage, resume: bool| {
        let mut c =
            cfg("builtin:tiny-s2-mb2", 1, 2, 2, steps, stage, ScheduleKind::OneF1B, Dtype::F32);
        c.checkpoint_dir = Some(dir.clone());
        c.resume = resume;
        c
    };
    let first = train(&mk(3, S1, false)).unwrap();
    let second = train(&mk(3, S2, true)).unwrap();
    assert_eq!(second.logs[0].step, 3);
    let mut combined = losses(&first);
    combined.extend(losses(&second));
    assert_close(&losses(&straight), &combined, 1e-4, "1 -> 2 reshard resume");
    // and back: the stage-2 checkpoint written above resumes as stage 1
    let third = train(&mk(2, S1, true)).unwrap();
    assert_eq!(third.logs[0].step, 6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stage_mismatches_touching_0_or_3_rejected() {
    let cases: &[(ShardingStage, ShardingStage)] =
        &[(S0, S1), (S1, S0), (S3, S2), (S2, S3), (S3, S0), (S0, S3)];
    for &(save, resume) in cases {
        let dir = resume_dir(&format!("rej{}{}", save.index(), resume.index()));
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |stage: ShardingStage, do_resume: bool| {
            let mut c =
                cfg("builtin:tiny-s2-mb2", 1, 2, 2, 2, stage, ScheduleKind::OneF1B, Dtype::F32);
            c.checkpoint_dir = Some(dir.clone());
            c.resume = do_resume;
            c
        };
        train(&mk(save, false)).unwrap();
        let err = train(&mk(resume, true)).unwrap_err().to_string();
        assert!(
            err.contains("sharding stage"),
            "{} -> {}: wanted a stage-compat error, got {err}",
            save.index(),
            resume.index()
        );
        std::fs::remove_dir_all(&dir).ok();
    }
}

// =========================================================================
// ZeRO-3 residency: gather-use-drop keeps peak params per-layer
// =========================================================================

#[test]
fn stage3_gather_residency_is_per_layer_not_per_model() {
    // one worker hosts ALL 4 chunks (v = 4): without gather-use-drop the
    // full-parameter residency would be the whole model; with it the
    // measured high-water mark is bounded by 2 gathered chunks (current
    // + one prefetched) — the mem model's transient term
    let spec = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
    let max_stage = (0..spec.n_stages).map(|g| spec.stage_params(g)).max().unwrap() as u64;
    let total = spec.total_params() as u64;
    let r = run(
        "builtin:tiny-s4-mb2",
        1,
        2,
        4,
        3,
        S3,
        ScheduleKind::Interleaved1F1B { v: 4 },
        Dtype::F32,
    );
    let peak = r.zero3_peak_gathered_floats;
    assert!(peak > 0, "stage 3 must gather");
    assert!(
        peak <= 2 * max_stage,
        "peak {peak} exceeds the 2-layer gather-use-drop bound {}",
        2 * max_stage
    );
    assert!(
        peak < total,
        "peak {peak} must stay below the full model's {total} params"
    );
    // stages 0-2 never run the on-demand gather machinery
    let ddp = run("builtin:tiny-s4-mb2", 1, 2, 4, 2, S2, ScheduleKind::OneF1B, Dtype::F32);
    assert_eq!(ddp.zero3_peak_gathered_floats, 0);
    // and the optimizer shard really is 1/dp-sized: stage 3 at dp=2
    // holds half the DDP state
    let s0 = run("builtin:tiny-s4-mb2", 1, 2, 4, 2, S0, ScheduleKind::OneF1B, Dtype::F32);
    assert!(
        // slack covers the ceil() of odd per-chunk splits
        2 * r.opt_state_bytes_per_rank <= s0.opt_state_bytes_per_rank + 64,
        "sharded optimizer state {} vs DDP {}",
        r.opt_state_bytes_per_rank,
        s0.opt_state_bytes_per_rank
    );
}

// =========================================================================
// feature-gated zero-matrix sweep (CI: `cargo test --features zero-matrix`)
// =========================================================================

#[cfg(feature = "zero-matrix")]
mod zero_matrix {
    use super::*;

    #[test]
    fn zero_matrix_smokes() {
        // stage ∈ {0,1,2,3} × precision ∈ {fp32, bf16} 5-step smokes on
        // the full miniature grid (tp2 × pp2 × dp2), each pinned to its
        // precision-matched DDP reference
        for precision in [Dtype::F32, Dtype::Bf16] {
            let reference =
                run("builtin:tiny-s2-mb2", 2, 2, 2, 5, S0, ScheduleKind::OneF1B, precision);
            assert!(reference.final_loss().is_finite());
            for stage in [S1, S2, S3] {
                let r = run("builtin:tiny-s2-mb2", 2, 2, 2, 5, stage, ScheduleKind::OneF1B, precision);
                assert_eq!(r.world_size, 8);
                assert_eq!(
                    losses(&reference),
                    losses(&r),
                    "{} stage {stage} must match stage-0 bitwise",
                    precision.name()
                );
            }
        }
    }
}
