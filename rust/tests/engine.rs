//! End-to-end tests of the real training engine (coordinator + runtime +
//! collectives + ZeRO-1 over the AOT artifacts).
//!
//! The key invariants mirror what makes distributed training *correct*:
//! every parallelisation of the same (model, data, optimizer) must walk
//! the same loss trajectory as the serial baseline.

use std::path::PathBuf;

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::optim::AdamConfig;

fn artifacts_root() -> PathBuf {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        root.join("tiny-s1-mb2/meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    root
}

fn run(bundle: &str, dp: usize, m: u32, steps: u32, zero1: bool, sched: ScheduleKind) -> TrainReport {
    train(&EngineConfig {
        artifacts_root: artifacts_root(),
        bundle: bundle.into(),
        dp,
        schedule: sched,
        microbatches: m,
        steps,
        adam: AdamConfig::default(),
        lr_schedule: None,
        zero1,
        seed: 42,
        log_every: 0,
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
    })
    .expect("training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

#[test]
fn pipeline_matches_single_stage_trajectory() {
    // THE pipeline-parallel correctness invariant: a 2-stage 1F1B pipeline
    // must reproduce the fused single-stage loss trajectory exactly (same
    // data, same init keys per stage, same optimizer).
    let single = run("tiny-s1-mb2", 1, 2, 5, false, ScheduleKind::OneF1B);
    let piped = run("tiny-s2-mb2", 1, 2, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&single), &losses(&piped), 2e-3, "pipeline vs single");
    // loss must actually move
    assert!(piped.final_loss() < piped.initial_loss());
}

#[test]
fn data_parallel_matches_serial_trajectory() {
    // dp=2 with m=2 consumes the same 4 samples/step as dp=1 with m=4
    // (the BatchStream interleaves rows across ranks), so the mean loss
    // trajectories must match.
    let serial = run("tiny-s2-mb2", 1, 4, 5, false, ScheduleKind::OneF1B);
    let dp2 = run("tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&serial), &losses(&dp2), 2e-3, "dp2 vs serial");
}

#[test]
fn zero1_matches_ddp_trajectory_e2e() {
    // turning ZeRO-1 on must not change the numerics, only the memory
    let ddp = run("tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    let z1 = run("tiny-s2-mb2", 2, 2, 5, true, ScheduleKind::OneF1B);
    assert_close(&losses(&ddp), &losses(&z1), 1e-3, "zero1 vs ddp");
}

#[test]
fn gpipe_matches_1f1b_numerics() {
    // schedules reorder compute but cannot change the gradients
    let f1b = run("tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::OneF1B);
    let gp = run("tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::GPipe);
    assert_close(&losses(&f1b), &losses(&gp), 1e-3, "gpipe vs 1f1b");
}

#[test]
fn four_stage_pipeline_trains() {
    // deeper pipeline on the mini model, saturated (m >= p)
    let r = run("mini-s4-mb1", 1, 4, 6, false, ScheduleKind::OneF1B);
    assert_eq!(r.world_size, 4);
    assert!(r.final_loss() < r.initial_loss(), "{:?}", losses(&r));
    assert!(r.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
}

#[test]
fn pp2_dp2_zero1_full_stack() {
    // the full 2x2 grid with sharded optimizer — the paper's layout in
    // miniature (minus TP, which the perf model covers)
    let r = run("mini-s2-mb2", 2, 2, 6, true, ScheduleKind::OneF1B);
    assert_eq!(r.world_size, 4);
    assert!(r.final_loss() < r.initial_loss());
    assert!(r.comm_bytes > 0, "DP must move bytes through collectives");
}

#[test]
fn report_accounting_sane() {
    let r = run("tiny-s2-mb2", 2, 4, 3, false, ScheduleKind::OneF1B);
    // tokens/step = mbs * seq * m * dp = 2*32*4*2
    assert_eq!(r.tokens_per_step, 2 * 32 * 4 * 2);
    assert!(r.mean_step_time_s > 0.0);
    assert!(r.tokens_per_sec > 0.0);
    assert_eq!(r.logs.len(), 3);
    assert_eq!(r.total_params, 134_912);
}

#[test]
fn unsaturated_pipeline_still_correct() {
    // m < p: bubble-heavy but numerically identical; engine must not hang
    let r = run("mini-s4-mb1", 1, 2, 3, false, ScheduleKind::OneF1B);
    assert!(r.logs.len() == 3 && r.final_loss().is_finite());
}

#[test]
fn checkpoint_resume_continues_trajectory() {
    // 6 straight steps == 3 steps + checkpoint + resume for 3 more, with
    // ZeRO-1 sharded optimizer state across dp=2 (per-rank shards).
    let dir = std::env::temp_dir().join(format!("fllm-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let straight = run("tiny-s2-mb2", 2, 2, 6, true, ScheduleKind::OneF1B);

    let mk = |steps: u32, resume: bool| EngineConfig {
        artifacts_root: artifacts_root(),
        bundle: "tiny-s2-mb2".into(),
        dp: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 2,
        steps,
        adam: AdamConfig::default(),
        lr_schedule: None,
        zero1: true,
        seed: 42,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 0,
        resume,
    };
    let first = train(&mk(3, false)).unwrap();
    let second = train(&mk(3, true)).unwrap();

    let mut combined = losses(&first);
    combined.extend(losses(&second));
    // resumed steps carry absolute indices
    assert_eq!(second.logs[0].step, 3);
    assert_close(&losses(&straight), &combined, 1e-4, "resume vs straight");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_shape_mismatch_rejected() {
    let dir = std::env::temp_dir().join(format!("fllm-mismatch-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = |dp: usize, resume: bool| EngineConfig {
        artifacts_root: artifacts_root(),
        bundle: "tiny-s2-mb2".into(),
        dp,
        microbatches: 2,
        steps: 2,
        seed: 42,
        checkpoint_dir: Some(dir.clone()),
        resume,
        ..Default::default()
    };
    train(&mk(1, false)).unwrap();
    // resuming with a different dp must be refused
    assert!(train(&mk(2, true)).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn determinism_same_seed_same_curve() {
    let a = run("tiny-s2-mb2", 1, 2, 4, false, ScheduleKind::OneF1B);
    let b = run("tiny-s2-mb2", 1, 2, 4, false, ScheduleKind::OneF1B);
    assert_eq!(losses(&a), losses(&b), "engine must be deterministic");
}
