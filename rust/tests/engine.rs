//! End-to-end tests of the real training engine (coordinator + runtime +
//! collectives + tensor parallelism + ZeRO-1).
//!
//! Two tiers:
//!
//! * **builtin** — the pure-Rust reference stages (`builtin:*` bundles).
//!   Always run: no artifacts, no PJRT.  These carry the schedule
//!   invariants, most importantly that every parallelisation/schedule of
//!   the same (model, data, optimizer) walks the same loss trajectory —
//!   interleaved 1F1B over virtual stages AND tensor-parallel sharding
//!   (tp = 1/2/4 equivalence, the §II.B pillar executed for real).
//! * **artifacts** — the AOT JAX/Pallas bundles.  These skip (with a
//!   note) when `make artifacts` has not run or no PJRT client exists.
//!
//! The feature-gated `tp_matrix` module (`--features tp-matrix`) sweeps a
//! small tp × pp × dp grid so the sharded paths cannot rot behind the
//! default tp = 1 (CI runs it).

use std::path::PathBuf;

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::optim::AdamConfig;
use frontier_llm::perf::{builtin_tp_ar_floats_per_microbatch, builtin_tp_grad_sync_floats_per_step};
use frontier_llm::zero::ShardingStage;

/// Artifact root, or `None` (skip) when artifacts are absent.
fn artifacts_root() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("tiny-s2-mb2/meta.json").exists() {
        Some(root)
    } else {
        eprintln!("skipping: artifacts missing — run `make artifacts` to cover the PJRT path");
        None
    }
}

fn cfg(bundle: &str, dp: usize, m: u32, steps: u32, zero1: bool, sched: ScheduleKind) -> EngineConfig {
    EngineConfig {
        artifacts_root: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        bundle: bundle.into(),
        dp,
        tp: 1,
        schedule: sched,
        microbatches: m,
        steps,
        adam: AdamConfig::default(),
        lr_schedule: None,
        zero_stage: if zero1 { ShardingStage::OptimizerStates } else { ShardingStage::Ddp },
        seed: 42,
        log_every: 0,
        checkpoint_dir: None,
        checkpoint_every: 0,
        resume: false,
        ..Default::default()
    }
}

fn run(bundle: &str, dp: usize, m: u32, steps: u32, zero1: bool, sched: ScheduleKind) -> TrainReport {
    train(&cfg(bundle, dp, m, steps, zero1, sched)).expect("training must succeed")
}

/// Like [`run`] but with a tensor-parallel degree.
fn run_tp(bundle: &str, tp: usize, dp: usize, m: u32, steps: u32, zero1: bool, sched: ScheduleKind) -> TrainReport {
    let mut c = cfg(bundle, dp, m, steps, zero1, sched);
    c.tp = tp;
    train(&c).expect("TP training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

// =========================================================================
// builtin backend: always runnable
// =========================================================================

#[test]
fn builtin_interleaved_matches_1f1b_trajectory() {
    // THE virtual-stage correctness invariant: interleaving reorders
    // compute and splits workers into chunk slots, but cannot change the
    // numerics.  Same 4-stage model as a 4-worker 1F1B pipeline, a
    // 2-worker x 2-chunk interleaved pipeline, and a 1-worker x 4-chunk
    // one — identical loss trajectories.
    let f1b = run("builtin:tiny-s4-mb2", 1, 4, 5, false, ScheduleKind::OneF1B);
    let v2 = run(
        "builtin:tiny-s4-mb2",
        1,
        4,
        5,
        false,
        ScheduleKind::Interleaved1F1B { v: 2 },
    );
    let v4 = run(
        "builtin:tiny-s4-mb2",
        1,
        4,
        5,
        false,
        ScheduleKind::Interleaved1F1B { v: 4 },
    );
    assert_close(&losses(&f1b), &losses(&v2), 2e-3, "interleaved v2 vs 1f1b");
    assert_close(&losses(&f1b), &losses(&v4), 2e-3, "interleaved v4 vs 1f1b");
    // the worker grids really differ: p = n_stages / v
    assert_eq!(f1b.world_size, 4);
    assert_eq!(v2.world_size, 2);
    assert_eq!(v4.world_size, 1);
}

#[test]
fn builtin_gpipe_matches_1f1b_numerics() {
    let f1b = run("builtin:tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::OneF1B);
    let gp = run("builtin:tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::GPipe);
    assert_close(&losses(&f1b), &losses(&gp), 1e-3, "gpipe vs 1f1b");
}

#[test]
fn builtin_loss_descends_under_interleaving() {
    // the engine must actually learn through the chunked path
    let mut c = cfg(
        "builtin:tiny-s4-mb2",
        1,
        4,
        8,
        false,
        ScheduleKind::Interleaved1F1B { v: 2 },
    );
    c.adam.lr = 2e-2;
    let r = train(&c).unwrap();
    assert!(
        r.final_loss() < r.initial_loss(),
        "loss must descend: {:?}",
        losses(&r)
    );
    assert!(r.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
}

#[test]
fn builtin_data_parallel_matches_serial() {
    // dp=2 with m=2 consumes the same 4 samples/step as dp=1 with m=4
    let serial = run("builtin:tiny-s2-mb2", 1, 4, 5, false, ScheduleKind::OneF1B);
    let dp2 = run("builtin:tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&serial), &losses(&dp2), 2e-3, "dp2 vs serial");
}

#[test]
fn builtin_zero1_matches_ddp() {
    let ddp = run("builtin:tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    let z1 = run("builtin:tiny-s2-mb2", 2, 2, 5, true, ScheduleKind::OneF1B);
    assert_close(&losses(&ddp), &losses(&z1), 1e-3, "zero1 vs ddp");
}

#[test]
fn builtin_full_grid_interleaved_zero1() {
    // the full stack in miniature: 2 workers x 2 chunks x dp2, ZeRO-1
    let r = run(
        "builtin:tiny-s4-mb2",
        2,
        4,
        5,
        true,
        ScheduleKind::Interleaved1F1B { v: 2 },
    );
    assert_eq!(r.world_size, 4); // (4 stages / v=2) x dp2
    assert!(r.comm_bytes > 0, "chunked p2p + DP must move bytes");
    assert!(r.final_loss().is_finite());
    // and it matches the unchunked runs numerically
    let plain = run("builtin:tiny-s4-mb2", 2, 4, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&plain), &losses(&r), 2e-3, "interleaved+zero1 vs plain");
}

#[test]
fn builtin_single_stage_fused_path() {
    let mut c = cfg("builtin:tiny-s1-mb2", 1, 4, 8, false, ScheduleKind::OneF1B);
    c.adam.lr = 2e-2;
    let r = train(&c).unwrap();
    assert_eq!(r.world_size, 1);
    assert!(r.final_loss() < r.initial_loss(), "{:?}", losses(&r));
}

#[test]
fn builtin_report_accounting() {
    let r = run("builtin:tiny-s2-mb2", 2, 4, 3, false, ScheduleKind::OneF1B);
    // tokens/step = mbs * seq * m * dp = 2*8*4*2
    assert_eq!(r.tokens_per_step, 2 * 8 * 4 * 2);
    assert!(r.mean_step_time_s > 0.0);
    assert!(r.tokens_per_sec > 0.0);
    assert_eq!(r.logs.len(), 3);
}

#[test]
fn builtin_determinism_same_seed_same_curve() {
    let a = run("builtin:tiny-s4-mb2", 1, 4, 4, false, ScheduleKind::Interleaved1F1B { v: 2 });
    let b = run("builtin:tiny-s4-mb2", 1, 4, 4, false, ScheduleKind::Interleaved1F1B { v: 2 });
    assert_eq!(losses(&a), losses(&b), "engine must be deterministic");
}

#[test]
fn builtin_interleaved_checkpoint_resume() {
    // checkpoints are keyed by GLOBAL stage, so a chunked run resumes
    // exactly: 6 straight steps == 3 + checkpoint + 3
    let dir = std::env::temp_dir().join(format!("fllm-bi-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    let straight = run("builtin:tiny-s4-mb2", 1, 4, 6, false, sched);

    let mk = |steps: u32, resume: bool| {
        let mut c = cfg("builtin:tiny-s4-mb2", 1, 4, steps, false, sched);
        c.checkpoint_dir = Some(dir.clone());
        c.resume = resume;
        c
    };
    let first = train(&mk(3, false)).unwrap();
    let second = train(&mk(3, true)).unwrap();
    assert_eq!(second.logs[0].step, 3);
    let mut combined = losses(&first);
    combined.extend(losses(&second));
    assert_close(&losses(&straight), &combined, 1e-4, "resume vs straight");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn builtin_rejects_unaligned_interleave() {
    // v must divide the stage count, and m must align with the rank grid
    let bad_v = cfg("builtin:tiny-s4-mb2", 1, 4, 2, false, ScheduleKind::Interleaved1F1B { v: 3 });
    assert!(train(&bad_v).is_err());
    let bad_m = cfg("builtin:tiny-s4-mb2", 1, 3, 2, false, ScheduleKind::Interleaved1F1B { v: 2 });
    assert!(train(&bad_m).is_err());
}

// =========================================================================
// tensor parallelism: sharded builtin stages, real per-layer all-reduces
// =========================================================================

#[test]
fn builtin_tp_matches_dense_trajectory_20_steps() {
    // THE tensor-parallel correctness invariant (§II.B executed): sharding
    // every stage column/row-wise and routing per-layer all-reduces
    // through real collectives cannot change the numerics.  tp = 1/2/4
    // over >= 20 steps must walk the same loss trajectory within f32
    // tolerance.
    let dense = run("builtin:tiny-s2-mb2", 1, 4, 20, false, ScheduleKind::OneF1B);
    let tp2 = run_tp("builtin:tiny-s2-mb2", 2, 1, 4, 20, false, ScheduleKind::OneF1B);
    let tp4 = run_tp("builtin:tiny-s2-mb2", 4, 1, 4, 20, false, ScheduleKind::OneF1B);
    assert_close(&losses(&dense), &losses(&tp2), 5e-3, "tp2 vs dense");
    assert_close(&losses(&dense), &losses(&tp4), 5e-3, "tp4 vs dense");
    // the worlds really differ: pp × dp × tp threads
    assert_eq!(dense.world_size, 2);
    assert_eq!(tp2.world_size, 4);
    assert_eq!(tp4.world_size, 8);
    // and the sharded runs really communicated
    assert!(tp2.tp_ar_rounds > 0 && tp2.tp_ar_bytes > 0);
}

#[test]
fn builtin_tp2_pp2_grid_matches_dense() {
    // 2-D model grid: tp=2 × pp=2 (via v=2 chunking of 4 stages) against
    // the dense 4-worker pipeline, >= 20 steps
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    let dense = run("builtin:tiny-s4-mb2", 1, 4, 20, false, ScheduleKind::OneF1B);
    let grid = run_tp("builtin:tiny-s4-mb2", 2, 1, 4, 20, false, sched);
    assert_close(&losses(&dense), &losses(&grid), 5e-3, "tp2×pp2 vs dense");
    assert_eq!(grid.world_size, 4); // 2 pipeline cells × 2 shards
}

#[test]
fn builtin_tp_full_grid_dp_zero1() {
    // the full 3-D stack in miniature: tp2 × pp2 × dp2 with ZeRO-1
    let plain = run("builtin:tiny-s2-mb2", 2, 2, 10, false, ScheduleKind::OneF1B);
    let grid = run_tp("builtin:tiny-s2-mb2", 2, 2, 2, 10, true, ScheduleKind::OneF1B);
    assert_close(&losses(&plain), &losses(&grid), 5e-3, "tp2×dp2+zero1 vs plain");
    assert_eq!(grid.world_size, 8);
    assert!(grid.comm_bytes > 0);
}

#[test]
fn builtin_tp_loss_descends() {
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 4, 8, false, ScheduleKind::OneF1B);
    c.tp = 2;
    c.adam.lr = 2e-2;
    let r = train(&c).unwrap();
    assert!(
        r.final_loss() < r.initial_loss(),
        "loss must descend under TP: {:?}",
        losses(&r)
    );
    assert!(r.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
}

#[test]
fn builtin_tp_determinism() {
    let a = run_tp("builtin:tiny-s2-mb2", 2, 1, 4, 5, false, ScheduleKind::OneF1B);
    let b = run_tp("builtin:tiny-s2-mb2", 2, 1, 4, 5, false, ScheduleKind::OneF1B);
    assert_eq!(losses(&a), losses(&b), "TP engine must be deterministic");
}

#[test]
fn builtin_tp_checkpoint_resume() {
    // checkpoints are keyed (global stage, tp rank): a sharded run must
    // resume exactly — 6 straight steps == 3 + checkpoint + 3
    let dir = std::env::temp_dir().join(format!("fllm-tp-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let straight = run_tp("builtin:tiny-s2-mb2", 2, 1, 4, 6, false, ScheduleKind::OneF1B);

    let mk = |steps: u32, resume: bool| {
        let mut c = cfg("builtin:tiny-s2-mb2", 1, 4, steps, false, ScheduleKind::OneF1B);
        c.tp = 2;
        c.checkpoint_dir = Some(dir.clone());
        c.resume = resume;
        c
    };
    let first = train(&mk(3, false)).unwrap();
    let second = train(&mk(3, true)).unwrap();
    assert_eq!(second.logs[0].step, 3);
    let mut combined = losses(&first);
    combined.extend(losses(&second));
    assert_close(&losses(&straight), &combined, 1e-4, "tp resume vs straight");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn builtin_rechunk_resume_across_v() {
    // checkpoints are keyed by GLOBAL stage, so the same bundle resumes
    // under a different pipeline chunking: v=2 checkpoint -> v=1 resume
    let dir = std::env::temp_dir().join(format!("fllm-rechunk-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let straight = run("builtin:tiny-s4-mb2", 1, 4, 6, false, ScheduleKind::OneF1B);

    let mk = |steps: u32, resume: bool, sched: ScheduleKind| {
        let mut c = cfg("builtin:tiny-s4-mb2", 1, 4, steps, false, sched);
        c.checkpoint_dir = Some(dir.clone());
        c.resume = resume;
        c
    };
    let first = train(&mk(3, false, ScheduleKind::Interleaved1F1B { v: 2 })).unwrap();
    let second = train(&mk(3, true, ScheduleKind::OneF1B)).unwrap();
    assert_eq!(second.logs[0].step, 3);
    let mut combined = losses(&first);
    combined.extend(losses(&second));
    assert_close(&losses(&straight), &combined, 2e-3, "re-chunked resume vs straight");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn builtin_tp_rejects_bad_shapes() {
    // tp must divide hidden (16) and vocab (64)
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 4, 2, false, ScheduleKind::OneF1B);
    c.tp = 3;
    assert!(train(&c).is_err());
    // artifact bundles cannot shard
    let mut c = cfg("tiny-s2-mb2", 1, 4, 2, false, ScheduleKind::OneF1B);
    c.tp = 2;
    assert!(train(&c).is_err());
    // resuming a tp=2 checkpoint with tp=1 is a shape mismatch
    let dir = std::env::temp_dir().join(format!("fllm-tp-mismatch-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = cfg("builtin:tiny-s2-mb2", 1, 2, 2, false, ScheduleKind::OneF1B);
    c.tp = 2;
    c.checkpoint_dir = Some(dir.clone());
    train(&c).unwrap();
    c.tp = 1;
    c.resume = true;
    assert!(train(&c).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tp_comm_bytes_match_analytic() {
    // THE "benchmark = run" contract for TP (the PR-1 treatment of the
    // pipeline bubble, applied to §II.B): the payload bytes measured by
    // the instrumented SubGroups must equal perf's analytic TP comm term
    // EXACTLY — per micro-batch all-reduces plus the per-step replicated-
    // gradient sync — for tp ∈ {2, 4, 8}.
    let (tokens, hidden) = (2 * 8, 16); // tiny: mbs×seq, d
    for tp in [2usize, 4, 8] {
        let (m, steps, k) = (2u32, 3u32, 2u64);
        let r = run_tp("builtin:tiny-s2-mb2", tp, 1, m, steps, false, ScheduleKind::OneF1B);
        let per_mb = builtin_tp_ar_floats_per_microbatch(k, tokens, hidden);
        let per_step_sync = builtin_tp_grad_sync_floats_per_step(k, hidden);
        let want = 4 * steps as u64 * (m as u64 * per_mb + per_step_sync);
        assert_eq!(
            r.tp_ar_bytes, want,
            "tp={tp}: measured {} vs analytic {want}",
            r.tp_ar_bytes
        );
    }
    // the fused single-stage path embeds once (one fewer all-reduce)
    let r = run_tp("builtin:tiny-s1-mb2", 2, 1, 2, 2, false, ScheduleKind::OneF1B);
    let want = 4 * 2 * (2 * builtin_tp_ar_floats_per_microbatch(1, tokens, hidden)
        + builtin_tp_grad_sync_floats_per_step(1, hidden));
    assert_eq!(r.tp_ar_bytes, want, "fused single-stage");
    // data parallelism multiplies the moved volume by dp (per-replica
    // micro-batches each run the full all-reduce set)
    let r = run_tp("builtin:tiny-s2-mb2", 2, 2, 2, 2, false, ScheduleKind::OneF1B);
    let want = 2 * 4 * 2 * (2 * builtin_tp_ar_floats_per_microbatch(2, tokens, hidden)
        + builtin_tp_grad_sync_floats_per_step(2, hidden));
    assert_eq!(r.tp_ar_bytes, want, "dp=2 doubles TP payload");
}

// =========================================================================
// feature-gated tp × pp matrix (CI: `cargo test --features tp-matrix`)
// =========================================================================

#[cfg(feature = "tp-matrix")]
mod tp_matrix {
    use super::*;

    #[test]
    fn tp_matrix_trajectories_agree() {
        // every point of the tp × (pp via v) × dp grid must reproduce the
        // dense serial trajectory on the same 4-stage bundle
        let reference = run("builtin:tiny-s4-mb2", 1, 4, 8, false, ScheduleKind::OneF1B);
        for tp in [1usize, 2, 4] {
            for v in [1u32, 2, 4] {
                for dp in [1usize, 2] {
                    let m = 4 / dp as u32; // same 4 samples/step
                    let sched = ScheduleKind::Interleaved1F1B { v };
                    if m % (4 / v) != 0 {
                        continue; // interleave alignment
                    }
                    let r = run_tp("builtin:tiny-s4-mb2", tp, dp, m, 8, dp > 1, sched);
                    assert_close(
                        &losses(&reference),
                        &losses(&r),
                        6e-3,
                        &format!("tp{tp} v{v} dp{dp}"),
                    );
                }
            }
        }
    }
}

// =========================================================================
// AOT artifact bundles: skip without `make artifacts`
// =========================================================================

#[test]
fn pipeline_matches_single_stage_trajectory() {
    // THE pipeline-parallel correctness invariant: a 2-stage 1F1B pipeline
    // must reproduce the fused single-stage loss trajectory exactly (same
    // data, same init keys per stage, same optimizer).
    if artifacts_root().is_none() {
        return;
    }
    let single = run("tiny-s1-mb2", 1, 2, 5, false, ScheduleKind::OneF1B);
    let piped = run("tiny-s2-mb2", 1, 2, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&single), &losses(&piped), 2e-3, "pipeline vs single");
    // loss must actually move
    assert!(piped.final_loss() < piped.initial_loss());
}

#[test]
fn data_parallel_matches_serial_trajectory() {
    if artifacts_root().is_none() {
        return;
    }
    let serial = run("tiny-s2-mb2", 1, 4, 5, false, ScheduleKind::OneF1B);
    let dp2 = run("tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    assert_close(&losses(&serial), &losses(&dp2), 2e-3, "dp2 vs serial");
}

#[test]
fn zero1_matches_ddp_trajectory_e2e() {
    if artifacts_root().is_none() {
        return;
    }
    let ddp = run("tiny-s2-mb2", 2, 2, 5, false, ScheduleKind::OneF1B);
    let z1 = run("tiny-s2-mb2", 2, 2, 5, true, ScheduleKind::OneF1B);
    assert_close(&losses(&ddp), &losses(&z1), 1e-3, "zero1 vs ddp");
}

#[test]
fn gpipe_matches_1f1b_numerics() {
    if artifacts_root().is_none() {
        return;
    }
    let f1b = run("tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::OneF1B);
    let gp = run("tiny-s2-mb2", 1, 4, 4, false, ScheduleKind::GPipe);
    assert_close(&losses(&f1b), &losses(&gp), 1e-3, "gpipe vs 1f1b");
}

#[test]
fn interleaved_matches_1f1b_on_artifacts() {
    // the chunked engine path over REAL stage executables: mini has 4
    // stages, so v=2 runs a 2-worker x 2-chunk grid
    if artifacts_root().is_none() {
        return;
    }
    let f1b = run("mini-s4-mb1", 1, 4, 4, false, ScheduleKind::OneF1B);
    let v2 = run("mini-s4-mb1", 1, 4, 4, false, ScheduleKind::Interleaved1F1B { v: 2 });
    assert_close(&losses(&f1b), &losses(&v2), 2e-3, "interleaved vs 1f1b (artifacts)");
    assert_eq!(v2.world_size, 2);
}

#[test]
fn four_stage_pipeline_trains() {
    if artifacts_root().is_none() {
        return;
    }
    let r = run("mini-s4-mb1", 1, 4, 6, false, ScheduleKind::OneF1B);
    assert_eq!(r.world_size, 4);
    assert!(r.final_loss() < r.initial_loss(), "{:?}", losses(&r));
    assert!(r.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
}

#[test]
fn pp2_dp2_zero1_full_stack() {
    if artifacts_root().is_none() {
        return;
    }
    let r = run("mini-s2-mb2", 2, 2, 6, true, ScheduleKind::OneF1B);
    assert_eq!(r.world_size, 4);
    assert!(r.final_loss() < r.initial_loss());
    assert!(r.comm_bytes > 0, "DP must move bytes through collectives");
}

#[test]
fn report_accounting_sane() {
    if artifacts_root().is_none() {
        return;
    }
    let r = run("tiny-s2-mb2", 2, 4, 3, false, ScheduleKind::OneF1B);
    // tokens/step = mbs * seq * m * dp = 2*32*4*2
    assert_eq!(r.tokens_per_step, 2 * 32 * 4 * 2);
    assert!(r.mean_step_time_s > 0.0);
    assert!(r.tokens_per_sec > 0.0);
    assert_eq!(r.logs.len(), 3);
    assert_eq!(r.total_params, 134_912);
}

#[test]
fn unsaturated_pipeline_still_correct() {
    if artifacts_root().is_none() {
        return;
    }
    // m < p: bubble-heavy but numerically identical; engine must not hang
    let r = run("mini-s4-mb1", 1, 2, 3, false, ScheduleKind::OneF1B);
    assert!(r.logs.len() == 3 && r.final_loss().is_finite());
}

#[test]
fn checkpoint_resume_continues_trajectory() {
    let Some(root) = artifacts_root() else { return };
    // 6 straight steps == 3 steps + checkpoint + resume for 3 more, with
    // ZeRO-1 sharded optimizer state across dp=2 (per-rank shards).
    let dir = std::env::temp_dir().join(format!("fllm-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let straight = run("tiny-s2-mb2", 2, 2, 6, true, ScheduleKind::OneF1B);

    let mk = |steps: u32, resume: bool| EngineConfig {
        artifacts_root: root.clone(),
        bundle: "tiny-s2-mb2".into(),
        dp: 2,
        tp: 1,
        schedule: ScheduleKind::OneF1B,
        microbatches: 2,
        steps,
        adam: AdamConfig::default(),
        lr_schedule: None,
        zero_stage: ShardingStage::OptimizerStates,
        seed: 42,
        log_every: 0,
        checkpoint_dir: Some(dir.clone()),
        checkpoint_every: 0,
        resume,
        ..Default::default()
    };
    let first = train(&mk(3, false)).unwrap();
    let second = train(&mk(3, true)).unwrap();

    let mut combined = losses(&first);
    combined.extend(losses(&second));
    // resumed steps carry absolute indices
    assert_eq!(second.logs[0].step, 3);
    assert_close(&losses(&straight), &combined, 1e-4, "resume vs straight");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn checkpoint_dp_mismatch_repartitions() {
    // shape checks need no artifacts: the builtin bundle exercises them.
    // dp is deliberately NOT part of the checkpoint shape contract: the
    // optimizer state re-partitions across the new dp on load (the
    // elastic dp±1 path — tests/elastic.rs pins the trajectory bitwise)
    let dir = std::env::temp_dir().join(format!("fllm-mismatch-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mk = |dp: usize, resume: bool| EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp,
        microbatches: 2,
        steps: 2,
        seed: 42,
        checkpoint_dir: Some(dir.clone()),
        resume,
        ..Default::default()
    };
    train(&mk(1, false)).unwrap();
    let grown = train(&mk(2, true)).unwrap();
    assert_eq!(grown.logs[0].step, 2, "dp=2 resume of a dp=1 checkpoint continues");
    // the bundle, by contrast, stays a hard reject
    let mut other = mk(2, true);
    other.bundle = "builtin:tiny-s4-mb2".into();
    assert!(train(&other).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn determinism_same_seed_same_curve() {
    if artifacts_root().is_none() {
        return;
    }
    let a = run("tiny-s2-mb2", 1, 2, 4, false, ScheduleKind::OneF1B);
    let b = run("tiny-s2-mb2", 1, 2, 4, false, ScheduleKind::OneF1B);
    assert_eq!(losses(&a), losses(&b), "engine must be deterministic");
}
