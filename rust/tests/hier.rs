//! Engine-level tests of the topology-aware hierarchical collectives:
//! two-tier (intra-node / inter-node) sharded DP dataflow, ZeRO++-style
//! node-local secondary parameter partitions, the int8 blockwise-scaled
//! inter-node gradient wire, and the tunable ZeRO-3 prefetch window.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **Bitwise invariance** — 20-step loss AND grad-norm trajectories of
//!   the hierarchical path equal the flat path **bitwise** at fp32 (and
//!   on the bf16 grid) across dp × tp × pp × zero-stage × nodes, because
//!   a value-preserving wire folds node partials into exactly the flat
//!   rank-order sum.
//! * **Per-tier wire, pinned EXACTLY** — the engine's measured
//!   `*_intra_bytes` / `*_inter_bytes` counters equal the analytic
//!   per-tier `perf` terms exactly at dp ∈ {2, 4} × nodes ∈ {1, 2}, for
//!   the bucketed grad sync (AR and RS), the ZeRO-3 on-demand gathers
//!   (primary inter-node + secondary node-local), and the packed PP p2p.
//! * **int8 wire arithmetic** — inter-node bytes under the int8 wire
//!   equal exactly fp32/4 + 4 bytes per 128-float block per node (the
//!   blockwise scales), hence ≤ a quarter of the fp32 wire plus scale
//!   overhead; intra-node traffic is unchanged.
//! * **Prefetch residency** — `zero3_peak_gathered_floats` stays within
//!   the `(N + 1)`-chunk bound at every `--zero3-prefetch N`, without
//!   moving the trajectory.

use frontier_llm::collectives::chunk_bounds;
use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::perf::{
    builtin_pp_p2p_floats_per_step, builtin_zero3_hier_ag_tier_bytes, hier_grad_sync_tier_bytes,
    packed_dp_group_nodes,
};
use frontier_llm::precision::{Dtype, GradWire, INT8_BLOCK};
use frontier_llm::runtime::BuiltinSpec;
use frontier_llm::zero::ShardingStage;

const S0: ShardingStage = ShardingStage::Ddp;
const S1: ShardingStage = ShardingStage::OptimizerStates;
const S2: ShardingStage = ShardingStage::Gradients;
const S3: ShardingStage = ShardingStage::Parameters;

/// `nodes = 0` is the legacy flat path; `nodes >= 1` places the world
/// packed onto that many Frontier nodes and switches the sharded DP
/// collectives hierarchical.
#[allow(clippy::too_many_arguments)]
fn cfg(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    sched: ScheduleKind,
    precision: Dtype,
    nodes: u32,
    grad_wire: Option<GradWire>,
) -> EngineConfig {
    EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        schedule: sched,
        microbatches: m,
        steps,
        zero_stage: stage,
        precision,
        // small buckets so every chunk splits into many hier rounds
        grad_bucket_floats: 128,
        seed: 42,
        nodes,
        grad_wire,
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    bundle: &str,
    tp: usize,
    dp: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    sched: ScheduleKind,
    precision: Dtype,
    nodes: u32,
    grad_wire: Option<GradWire>,
) -> TrainReport {
    train(&cfg(bundle, tp, dp, m, steps, stage, sched, precision, nodes, grad_wire))
        .expect("training must succeed")
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn grad_norms(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.grad_norm).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

// =========================================================================
// THE acceptance grid: hier ≡ flat bitwise at fp32,
// dp ∈ {2, 4} × tp ∈ {1, 2} × pp shape × stage ∈ {0, 2, 3} × nodes ∈ {1, 2}
// =========================================================================

#[test]
fn hier_matches_flat_bitwise_fp32_20_steps_grid() {
    // pp = 2 runs the 2-stage bundle as a real pipeline; pp = 1 folds it
    // onto one worker via v = 2 chunking — both shapes per (dp, tp)
    let shapes: &[(ScheduleKind, &str, usize)] = &[
        (ScheduleKind::OneF1B, "pp2", 2),
        (ScheduleKind::Interleaved1F1B { v: 2 }, "pp1(v2)", 1),
    ];
    for &dp in &[2usize, 4] {
        for &tp in &[1usize, 2] {
            for &(sched, pshape, pp_workers) in shapes {
                for stage in [S0, S2, S3] {
                    let flat =
                        run("builtin:tiny-s2-mb2", tp, dp, 2, 20, stage, sched, Dtype::F32, 0, None);
                    for nodes in [1u32, 2] {
                        // packed placement caps a node at 8 GCDs
                        if dp * tp * pp_workers > 8 * nodes as usize {
                            continue;
                        }
                        let hier = run(
                            "builtin:tiny-s2-mb2",
                            tp,
                            dp,
                            2,
                            20,
                            stage,
                            sched,
                            Dtype::F32,
                            nodes,
                            None,
                        );
                        let label = format!("dp{dp} tp{tp} {pshape} stage {stage} nodes {nodes}");
                        assert_eq!(
                            losses(&flat),
                            losses(&hier),
                            "{label}: losses must be bitwise"
                        );
                        assert_eq!(
                            grad_norms(&flat),
                            grad_norms(&hier),
                            "{label}: grad norms must be bitwise"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn hier_matches_flat_bitwise_on_the_bf16_grid() {
    // the native bf16 wire is value-preserving over bf16 storage, so the
    // hierarchical fold collapses to the flat rank-order sum grid-bitwise
    for &(sched, pshape) in &[
        (ScheduleKind::OneF1B, "pp2"),
        (ScheduleKind::Interleaved1F1B { v: 2 }, "pp1(v2)"),
    ] {
        for stage in [S0, S1, S2, S3] {
            let flat =
                run("builtin:tiny-s2-mb2", 1, 2, 2, 20, stage, sched, Dtype::Bf16, 0, None);
            for nodes in [1u32, 2] {
                let hier = run(
                    "builtin:tiny-s2-mb2",
                    1,
                    2,
                    2,
                    20,
                    stage,
                    sched,
                    Dtype::Bf16,
                    nodes,
                    None,
                );
                assert_eq!(
                    losses(&flat),
                    losses(&hier),
                    "{pshape} stage {stage} nodes {nodes}: bf16 hier must stay bitwise"
                );
                assert_eq!(hier.steps_skipped, 0);
            }
        }
    }
}

// =========================================================================
// per-tier byte counters, pinned EXACTLY against the perf contract terms
// at dp ∈ {2, 4} × nodes ∈ {1, 2}
// =========================================================================

/// Per-rank gradient chunk sizes of the single-row (pp = 1 via v = 2,
/// tp = 1) tiny-s2 shape: one worker hosts both stages as chunks.
fn s2_chunk_params() -> Vec<u64> {
    let spec = BuiltinSpec::parse("builtin:tiny-s2-mb2").unwrap();
    (0..spec.n_stages).map(|g| spec.stage_params(g) as u64).collect()
}

#[test]
fn grad_sync_tier_bytes_pinned_exactly() {
    let chunks = s2_chunk_params();
    let total: u64 = chunks.iter().sum();
    let steps = 4u32;
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    for &dp in &[2usize, 4] {
        for nodes in [1u32, 2] {
            let node_of = packed_dp_group_nodes(0, 0, 1, dp, 1, nodes);
            for (stage, sharded) in [(S0, false), (S2, true)] {
                let r = run(
                    "builtin:tiny-s2-mb2",
                    1,
                    dp,
                    2,
                    steps,
                    stage,
                    sched,
                    Dtype::F32,
                    nodes,
                    None,
                );
                let (intra, inter) = hier_grad_sync_tier_bytes(
                    &chunks,
                    128,
                    &node_of,
                    4,
                    GradWire::F32,
                    sharded,
                );
                let label = format!("dp{dp} nodes{nodes} stage {stage}");
                assert_eq!(
                    r.dp_bucket_intra_bytes,
                    steps as u64 * intra,
                    "{label}: intra-tier grad sync pin"
                );
                assert_eq!(
                    r.dp_bucket_inter_bytes,
                    steps as u64 * inter,
                    "{label}: inter-tier grad sync pin"
                );
                // the legacy logical-payload counter is tier-agnostic and
                // must advance exactly as in flat mode
                assert_eq!(
                    r.dp_bucket_payload_bytes,
                    steps as u64 * 4 * total,
                    "{label}: legacy payload counter untouched"
                );
                // one node means no inter-node hop at all
                if nodes == 1 {
                    assert_eq!(r.dp_bucket_inter_bytes, 0, "{label}");
                }
                // stages 1/2 run the post-step updated-param AG on the
                // flat blocking path by design: no hier AG tier traffic
                if stage == S2 {
                    assert_eq!(r.dp_param_ag_intra_bytes, 0, "{label}: stage-2 AG stays flat");
                    assert_eq!(r.dp_param_ag_inter_bytes, 0, "{label}: stage-2 AG stays flat");
                }
            }
        }
    }
}

#[test]
fn zero3_hier_ag_tier_bytes_pinned_exactly() {
    // ZeRO-3 under hier: the FIRST use of a chunk per step gathers across
    // the DP group (two-tier); every later use is served from the
    // node-local secondary partition (ZeRO++ hpZ) — intra-node only
    let chunks = s2_chunk_params();
    let total: u64 = chunks.iter().sum();
    let (m, steps) = (2u32, 4u32);
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    for &dp in &[2usize, 4] {
        for nodes in [1u32, 2] {
            let node_of = packed_dp_group_nodes(0, 0, 1, dp, 1, nodes);
            let r = run(
                "builtin:tiny-s2-mb2",
                1,
                dp,
                m,
                steps,
                S3,
                sched,
                Dtype::F32,
                nodes,
                None,
            );
            let (intra, inter) =
                builtin_zero3_hier_ag_tier_bytes(&chunks, m as u64, &node_of, 4);
            let label = format!("dp{dp} nodes{nodes}");
            assert_eq!(
                r.dp_param_ag_intra_bytes,
                steps as u64 * intra,
                "{label}: intra-tier ZeRO-3 AG pin"
            );
            assert_eq!(
                r.dp_param_ag_inter_bytes,
                steps as u64 * inter,
                "{label}: inter-tier ZeRO-3 AG pin"
            );
            if nodes == 1 {
                assert_eq!(r.dp_param_ag_inter_bytes, 0, "{label}: one node, no inter hop");
            }
            // the legacy counter records DP-group gathers only: one
            // primary gather per chunk per step — strictly less wire than
            // the flat path's gather-per-use
            assert_eq!(
                r.dp_param_ag_bytes,
                steps as u64 * 4 * total,
                "{label}: primary-only legacy AG pin"
            );
            let flat = run(
                "builtin:tiny-s2-mb2",
                1,
                dp,
                m,
                steps,
                S3,
                sched,
                Dtype::F32,
                0,
                None,
            );
            assert!(
                r.dp_param_ag_bytes < flat.dp_param_ag_bytes,
                "{label}: secondary partitions must shed DP-group gathers \
                 ({} !< {})",
                r.dp_param_ag_bytes,
                flat.dp_param_ag_bytes
            );
            // the gradient-sync RS half is pinned like every other stage
            let (gi, ge) =
                hier_grad_sync_tier_bytes(&chunks, 128, &node_of, 4, GradWire::F32, true);
            assert_eq!(r.dp_bucket_intra_bytes, steps as u64 * gi, "{label}");
            assert_eq!(r.dp_bucket_inter_bytes, steps as u64 * ge, "{label}");
        }
    }
}

#[test]
fn pp_p2p_tier_split_follows_packed_placement() {
    // tiny: tokens = mbs × seq = 16, hidden = 16; 2-stage pipeline of
    // world = 2 ranks.  Packed onto 1 node both sit together (all
    // intra); onto 2 nodes the boundary crosses Slingshot (all inter).
    let (tokens, hidden, k) = (16u64, 16u64, 2u64);
    let (m, steps) = (2u32, 3u32);
    let floats = builtin_pp_p2p_floats_per_step(k, 2, m as u64, tokens, hidden);
    let want = steps as u64 * 4 * floats;
    for (nodes, intra, inter) in [(1u32, want, 0u64), (2, 0, want)] {
        let r = run(
            "builtin:tiny-s2-mb2",
            1,
            1,
            m,
            steps,
            S0,
            ScheduleKind::OneF1B,
            Dtype::F32,
            nodes,
            None,
        );
        assert_eq!(r.pp_p2p_payload_bytes, want, "nodes {nodes}: legacy p2p pin");
        assert_eq!(r.pp_p2p_intra_bytes, intra, "nodes {nodes}: intra p2p split");
        assert_eq!(r.pp_p2p_inter_bytes, inter, "nodes {nodes}: inter p2p split");
    }
    // the tier split always partitions the legacy counter
    let r = run(
        "builtin:tiny-s2-mb2",
        1,
        2,
        m,
        steps,
        S0,
        ScheduleKind::OneF1B,
        Dtype::F32,
        2,
        None,
    );
    assert_eq!(
        r.pp_p2p_intra_bytes + r.pp_p2p_inter_bytes,
        r.pp_p2p_payload_bytes,
        "tier split must partition the p2p payload"
    );
}

// =========================================================================
// the int8 blockwise-scaled inter-node gradient wire
// =========================================================================

#[test]
fn int8_wire_inter_bytes_exact_quarter_plus_scales() {
    let chunks = s2_chunk_params();
    let steps = 4u32;
    let sched = ScheduleKind::Interleaved1F1B { v: 2 };
    for &dp in &[2usize, 4] {
        // bucket split mirror: reduce-scatter partitions each chunk across
        // the dp owners FIRST, then cuts 128-float buckets per owner span,
        // each bucket carrying ceil(len / 128) blockwise f32 scales on the
        // int8 wire — so the block count depends on dp
        let blocks: u64 = chunks
            .iter()
            .flat_map(|&p| chunk_bounds(p as usize, dp))
            .map(|(lo, hi)| {
                let mut blocks = 0u64;
                let mut rem = (hi - lo) as u64;
                while rem > 0 {
                    let b = rem.min(128);
                    blocks += b.div_ceil(INT8_BLOCK as u64);
                    rem -= b;
                }
                blocks
            })
            .sum();
        let node_of = packed_dp_group_nodes(0, 0, 1, dp, 1, 2);
        let k = 2u64; // both placements split 2 ways across 2 nodes
        let f32_wire = run(
            "builtin:tiny-s2-mb2",
            1,
            dp,
            2,
            steps,
            S2,
            sched,
            Dtype::F32,
            2,
            Some(GradWire::F32),
        );
        let int8_wire = run(
            "builtin:tiny-s2-mb2",
            1,
            dp,
            2,
            steps,
            S2,
            sched,
            Dtype::F32,
            2,
            Some(GradWire::Int8),
        );
        let label = format!("dp{dp}");
        // pinned against the contract term...
        let (_, e8) =
            hier_grad_sync_tier_bytes(&chunks, 128, &node_of, 4, GradWire::Int8, true);
        assert_eq!(int8_wire.dp_bucket_inter_bytes, steps as u64 * e8, "{label}: int8 pin");
        // ...and by the EXACT arithmetic identity: a quarter of the fp32
        // wire plus one f32 scale per block per node
        assert_eq!(
            int8_wire.dp_bucket_inter_bytes,
            f32_wire.dp_bucket_inter_bytes / 4 + steps as u64 * 4 * k * blocks,
            "{label}: int8 = fp32/4 + blockwise scales"
        );
        // the acceptance bound follows: ≤ 1/4 + scale overhead
        assert!(
            int8_wire.dp_bucket_inter_bytes
                <= f32_wire.dp_bucket_inter_bytes / 4 + steps as u64 * 4 * k * blocks,
            "{label}"
        );
        // quantization happens on the inter-node hop only: the intra tier
        // rides the storage wire unchanged
        assert_eq!(
            int8_wire.dp_bucket_intra_bytes, f32_wire.dp_bucket_intra_bytes,
            "{label}: intra tier unaffected by the grad wire"
        );
        // the trajectory absorbs the (bounded, deterministic) wire error
        assert!(int8_wire.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()));
        assert_close(
            &losses(&f32_wire),
            &losses(&int8_wire),
            0.2,
            &format!("{label}: int8 trajectory"),
        );
    }
}

#[test]
fn int8_wire_is_deterministic_across_runs() {
    let mk = || {
        run(
            "builtin:tiny-s2-mb2",
            1,
            4,
            2,
            6,
            S2,
            ScheduleKind::Interleaved1F1B { v: 2 },
            Dtype::F32,
            2,
            Some(GradWire::Int8),
        )
    };
    let a = mk();
    let b = mk();
    assert_eq!(losses(&a), losses(&b), "int8 fold must not depend on arrival order");
    assert_eq!(grad_norms(&a), grad_norms(&b));
}

// =========================================================================
// tunable ZeRO-3 prefetch window: (N + 1)-chunk residency, trajectory-free
// =========================================================================

#[test]
fn zero3_prefetch_bounds_residency_without_moving_the_trajectory() {
    let spec = BuiltinSpec::parse("builtin:tiny-s4-mb2").unwrap();
    let max_stage = (0..spec.n_stages).map(|g| spec.stage_params(g)).max().unwrap() as u64;
    let mk = |prefetch: usize| {
        let mut c = cfg(
            "builtin:tiny-s4-mb2",
            1,
            2,
            4,
            3,
            S3,
            ScheduleKind::Interleaved1F1B { v: 4 },
            Dtype::F32,
            0,
            None,
        );
        c.zero3_prefetch = prefetch;
        train(&c).expect("training must succeed")
    };
    let baseline = mk(1);
    for n in [0usize, 1, 3] {
        let r = mk(n);
        let bound = (n as u64 + 1) * max_stage;
        assert!(
            r.zero3_peak_gathered_floats > 0 && r.zero3_peak_gathered_floats <= bound,
            "prefetch {n}: peak {} exceeds the (N+1)-chunk bound {bound}",
            r.zero3_peak_gathered_floats
        );
        assert_eq!(
            losses(&baseline),
            losses(&r),
            "prefetch {n}: lookahead depth must be trajectory-neutral"
        );
    }
}

// =========================================================================
// feature-gated hier-matrix sweep (CI: `cargo test --features hier-matrix`)
// =========================================================================

#[cfg(feature = "hier-matrix")]
mod hier_matrix {
    use super::*;

    #[test]
    fn hier_matrix_smokes() {
        // nodes ∈ {1, 2} × zero-stage ∈ {2, 3} × grad-wire ∈ {bf16, int8}
        // 5-step smokes under bf16 precision on the dp4 × v2 shape, each
        // checked against its flat reference: the native bf16 wire is
        // value-preserving (bitwise), the int8 wire requantizes (bounded
        // drift, finite throughout)
        let sched = ScheduleKind::Interleaved1F1B { v: 2 };
        for stage in [S2, S3] {
            let flat =
                run("builtin:tiny-s2-mb2", 1, 4, 2, 5, stage, sched, Dtype::Bf16, 0, None);
            assert!(flat.final_loss().is_finite());
            for nodes in [1u32, 2] {
                for wire in [GradWire::Bf16, GradWire::Int8] {
                    let r = run(
                        "builtin:tiny-s2-mb2",
                        1,
                        4,
                        2,
                        5,
                        stage,
                        sched,
                        Dtype::Bf16,
                        nodes,
                        Some(wire),
                    );
                    let label = format!("stage {stage} nodes {nodes} wire {}", wire.name());
                    assert!(
                        r.logs.iter().all(|l| l.loss.is_finite() && l.grad_norm.is_finite()),
                        "{label}: trajectory must stay finite"
                    );
                    match wire {
                        GradWire::Bf16 => assert_eq!(
                            losses(&flat),
                            losses(&r),
                            "{label}: native wire must match flat bitwise"
                        ),
                        _ => assert_close(&losses(&flat), &losses(&r), 0.2, &label),
                    }
                }
            }
        }
    }
}
