//! Engine-level tests of the expert-parallel subsystem: the MoE stage
//! family (`builtin:*-moe<E>k<K>-*`), the deterministic `all_to_all`
//! dispatch/combine wire, and its composition with tp × pp × dp × zero.
//!
//! The locks, mirroring the issue's acceptance criteria:
//!
//! * **Single-expert ≡ dense** — the `-moe1` (top-1) bundle carries no
//!   gate and routes every token to its one expert at full capacity, so
//!   its 20-step trajectory equals the dense bundle's **bitwise**, at
//!   fp32 AND bf16, across tp — same parameter count, same flat vector.
//! * **ep-invariance** — `ep ∈ {2, 4}` equals `ep = 1` **bitwise** at
//!   fp32 on the dp × tp × zero-stage grid: the capacity-bounded
//!   dispatch plan is data-local (identical at every ep), and the fp32
//!   a2a wire is value-preserving, so sharding expert *compute* moves
//!   FLOPs and bytes but never the trajectory.
//! * **a2a wire, pinned EXACTLY** — `moe_a2a_rounds` and
//!   `moe_a2a_payload_bytes` equal the analytic `perf::moe_a2a_*` terms
//!   exactly (payload halves exactly under the packed-bf16 wire); under
//!   `--nodes` the intra/inter tier split is pinned against
//!   `perf::moe_a2a_tier_bytes_per_step`, and the two tiers plus the
//!   self parts reassemble the full payload.
//! * **Capacity/drop accounting** — a tight capacity factor drops
//!   assignments deterministically and identically at every ep; a
//!   generous one (cap = tokens) drops nothing.
//! * **CLI** — `--experts/--moe-topk` rewrite the builtin bundle name
//!   and train end to end; misuse dies with a targeted error.
//!
//! The full ep ∈ {1,2,4} × zero-stage ∈ {0,2,3} × {fp32, bf16} grid
//! rides behind `--features moe-matrix` (CI).

use std::process::Command;

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig, TrainReport};
use frontier_llm::moe;
use frontier_llm::perf::{
    moe_a2a_payload_bytes_per_round, moe_a2a_rounds_per_step, moe_a2a_tier_bytes_per_step,
};
use frontier_llm::precision::Dtype;
use frontier_llm::runtime::BuiltinSpec;
use frontier_llm::zero::ShardingStage;

const S0: ShardingStage = ShardingStage::Ddp;
const S2: ShardingStage = ShardingStage::Gradients;
const S3: ShardingStage = ShardingStage::Parameters;

/// The workhorse shapes: `tiny` (d = 16, seq = 8) as a 2-stage pipeline,
/// dense vs 4-expert top-2.  tokens per micro-batch = mbs × seq = 16.
const DENSE: &str = "builtin:tiny-s2-mb2";
const MOE1: &str = "builtin:tiny-moe1k1-s2-mb2";
const MOE4: &str = "builtin:tiny-moe4k2-s2-mb2";
const TOKENS: usize = 16;
const HIDDEN: u64 = 16;
const EXPERTS: usize = 4;
const TOPK: usize = 2;

#[allow(clippy::too_many_arguments)]
fn cfg(
    bundle: &str,
    tp: usize,
    dp: usize,
    ep: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    precision: Dtype,
) -> EngineConfig {
    EngineConfig {
        bundle: bundle.into(),
        dp,
        tp,
        ep,
        schedule: ScheduleKind::OneF1B,
        microbatches: m,
        steps,
        zero_stage: stage,
        precision,
        grad_bucket_floats: 128,
        seed: 42,
        ..Default::default()
    }
}

#[allow(clippy::too_many_arguments)]
fn run(
    bundle: &str,
    tp: usize,
    dp: usize,
    ep: usize,
    m: u32,
    steps: u32,
    stage: ShardingStage,
    precision: Dtype,
) -> TrainReport {
    train(&cfg(bundle, tp, dp, ep, m, steps, stage, precision)).expect("training must succeed")
}

/// Bitwise view of a trajectory: step index, loss and grad-norm bits.
fn traj(r: &TrainReport) -> Vec<(u32, u32, u32)> {
    r.logs.iter().map(|l| (l.step, l.loss.to_bits(), l.grad_norm.to_bits())).collect()
}

fn losses(r: &TrainReport) -> Vec<f32> {
    r.logs.iter().map(|l| l.loss).collect()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * x.abs().max(1.0),
            "{what}: step {i}: {x} vs {y}"
        );
    }
}

// =========================================================================
// Single-expert MoE ≡ dense, bitwise — the contract the whole family
// is anchored to (no gate params at E = 1, capacity clamped to tokens)
// =========================================================================

#[test]
fn moe1_top1_matches_dense_bitwise_at_fp32_and_bf16() {
    // the -moe1 block is the dense block: same parameter count (no gate),
    // same flat vector, so even the grad-norm span partitioning agrees
    let dense_spec = BuiltinSpec::parse(DENSE).unwrap();
    let moe1_spec = BuiltinSpec::parse(MOE1).unwrap();
    assert_eq!(moe1_spec.total_params(), dense_spec.total_params());
    for precision in [Dtype::F32, Dtype::Bf16] {
        for &tp in &[1usize, 2] {
            let dense = run(DENSE, tp, 2, 1, 2, 20, S0, precision);
            let moe1 = run(MOE1, tp, 2, 1, 2, 20, S0, precision);
            assert_eq!(
                traj(&dense),
                traj(&moe1),
                "tp{tp} {}: -moe1 top-1 must equal dense bitwise",
                precision.name()
            );
            // single-expert routing is local arithmetic: no wire, no drops
            assert_eq!(moe1.moe_a2a_rounds, 0);
            assert_eq!(moe1.moe_a2a_payload_bytes, 0);
            assert_eq!(moe1.moe_dropped_tokens, 0);
        }
    }
    // and the dense engine never touches any MoE counter
    let dense = run(DENSE, 1, 2, 1, 2, 2, S0, Dtype::F32);
    assert_eq!(
        (dense.moe_a2a_rounds, dense.moe_a2a_payload_bytes, dense.moe_dropped_tokens),
        (0, 0, 0)
    );
}

// =========================================================================
// THE acceptance grid: ep ∈ {2, 4} ≡ ep = 1 bitwise at fp32,
// dp = 4 × tp ∈ {1, 2} × stage ∈ {0, 3}, 20 steps
// =========================================================================

#[test]
fn ep_is_trajectory_invariant_bitwise_at_fp32() {
    for &tp in &[1usize, 2] {
        for stage in [S0, S3] {
            let local = run(MOE4, tp, 4, 1, 2, 20, stage, Dtype::F32);
            assert!(
                local.final_loss() < local.initial_loss(),
                "tp{tp} {stage}: the MoE model must learn: {:?}",
                losses(&local)
            );
            for ep in [2usize, 4] {
                let sharded = run(MOE4, tp, 4, ep, 2, 20, stage, Dtype::F32);
                let label = format!("tp{tp} stage {stage} ep{ep}");
                assert_eq!(
                    traj(&local),
                    traj(&sharded),
                    "{label}: expert sharding must not move the fp32 trajectory"
                );
                // the data-local dispatch plan is identical at every ep
                assert_eq!(
                    local.moe_dropped_tokens, sharded.moe_dropped_tokens,
                    "{label}: drop accounting must be ep-invariant"
                );
                assert!(sharded.moe_a2a_rounds > 0, "{label}: ep > 1 must hit the wire");
            }
        }
    }
}

#[test]
fn ep_runs_are_deterministic_across_reruns() {
    let a = run(MOE4, 1, 4, 2, 2, 10, S2, Dtype::F32);
    let b = run(MOE4, 1, 4, 2, 2, 10, S2, Dtype::F32);
    assert_eq!(traj(&a), traj(&b), "the a2a engine must be deterministic");
    assert_eq!(a.moe_a2a_payload_bytes, b.moe_a2a_payload_bytes);
    assert_eq!(a.moe_dropped_tokens, b.moe_dropped_tokens);
}

// =========================================================================
// a2a wire contracts, pinned EXACTLY against the perf terms
// =========================================================================

#[test]
fn a2a_rounds_and_payload_pinned_exactly() {
    let (n_stages, m, steps, dp) = (2u64, 2u64, 3u32, 4usize);
    let cap = moe::capacity(TOKENS, TOPK, EXPERTS, 1.25) as u64;
    assert_eq!(cap, 10, "tiny cap: ceil(1.25 * 16 * 2 / 4)");
    for ep in [2usize, 4] {
        let rounds = moe_a2a_rounds_per_step(n_stages, m, 1, dp as u64, ep as u64);
        for (precision, width) in [(Dtype::F32, 4u64), (Dtype::Bf16, 2u64)] {
            let r = run(MOE4, 1, dp, ep, m as u32, steps, S0, precision);
            let label = format!("ep{ep} {}", precision.name());
            assert_eq!(
                r.moe_a2a_rounds,
                steps as u64 * rounds,
                "{label}: dispatch + combine per chunk per micro-batch per EP column"
            );
            assert_eq!(
                r.moe_a2a_payload_bytes,
                r.moe_a2a_rounds
                    * moe_a2a_payload_bytes_per_round(ep as u64, EXPERTS as u64, cap, HIDDEN, width),
                "{label}: ep² parts of (E/ep)·cap·d elements at the wire width"
            );
            // flat mode (nodes = 0): no topology, no tier split
            assert_eq!((r.moe_a2a_intra_bytes, r.moe_a2a_inter_bytes), (0, 0), "{label}");
        }
    }
    // one literal guard against formula + engine co-drift:
    // tp·(dp/ep)·n_stages·2·m = 1·2·2·2·2
    assert_eq!(moe_a2a_rounds_per_step(2, 2, 1, 4, 2), 16);
    // and the packed-bf16 wire halves the payload exactly
    let fp32 = run(MOE4, 1, 2, 2, 2, 2, S0, Dtype::F32);
    let bf16 = run(MOE4, 1, 2, 2, 2, 2, S0, Dtype::Bf16);
    assert_eq!(2 * bf16.moe_a2a_payload_bytes, fp32.moe_a2a_payload_bytes);
}

#[test]
fn a2a_tier_split_pinned_under_packed_placement() {
    // pp2 × dp4 × tp1 = 8 ranks on 4 nodes (2 per node): each pp row's
    // EP group spans ranks {4p .. 4p+3} = nodes {2p, 2p, 2p+1, 2p+1} —
    // of its 12 src≠dst pairs, 4 stay on-node and 8 cross
    let (n_stages, m, steps, dp, ep, nodes) = (2u64, 2u64, 2u32, 4usize, 4usize, 4u32);
    let cap = moe::capacity(TOKENS, TOPK, EXPERTS, 1.25) as u64;
    let mut c = cfg(MOE4, 1, dp, ep, m as u32, steps, S2, Dtype::F32);
    c.nodes = nodes;
    let r = train(&c).expect("hierarchical MoE run must succeed");
    let (intra, inter) = moe_a2a_tier_bytes_per_step(
        n_stages, m, 2, 1, dp, ep, EXPERTS as u64, cap, HIDDEN, 4, nodes,
    );
    assert!(intra > 0 && inter > 0, "the placement must split both ways");
    assert_eq!(r.moe_a2a_intra_bytes, steps as u64 * intra, "intra-node tier pin");
    assert_eq!(r.moe_a2a_inter_bytes, steps as u64 * inter, "inter-node tier pin");
    // the two tiers plus the ep self parts reassemble the full payload
    let part = (EXPERTS / ep) as u64 * cap * HIDDEN * 4;
    let self_bytes = r.moe_a2a_rounds * ep as u64 * part;
    assert_eq!(
        r.moe_a2a_intra_bytes + r.moe_a2a_inter_bytes + self_bytes,
        r.moe_a2a_payload_bytes,
        "tier split + self parts == total payload"
    );
    // topology is accounting only: the fp32 wire is value-preserving, so
    // the hierarchical trajectory equals the flat one bitwise
    let flat = run(MOE4, 1, dp, ep, m as u32, steps, S2, Dtype::F32);
    assert_eq!(traj(&flat), traj(&r), "hier ≡ flat at fp32");
}

// =========================================================================
// Capacity factor and token-drop accounting
// =========================================================================

#[test]
fn tight_capacity_drops_tokens_deterministically_and_ep_invariantly() {
    // cf = 0.5: cap = ceil(0.5·16·2/4) = 4 slots per expert — the 32
    // assignments of a micro-batch cannot fit in 16 slots, so at least
    // 16 drop per scheduled block forward, at ANY ep
    let mk = |ep: usize, cf: f32| {
        let mut c = cfg(MOE4, 1, 2, ep, 2, 3, S0, Dtype::F32);
        c.capacity_factor = cf;
        train(&c).expect("training must survive drops")
    };
    let tight = mk(1, 0.5);
    assert!(tight.moe_dropped_tokens > 0, "cf 0.5 must overflow capacity");
    let tight_ep2 = mk(2, 0.5);
    assert_eq!(
        tight.moe_dropped_tokens, tight_ep2.moe_dropped_tokens,
        "the dispatch plan (and its drops) is data-local: identical at every ep"
    );
    assert_eq!(traj(&tight), traj(&tight_ep2), "dropped routing stays ep-invariant");
    // the tightened capacity also shows up on the wire, pinned exactly
    let cap = moe::capacity(TOKENS, TOPK, EXPERTS, 0.5) as u64;
    assert_eq!(cap, 4);
    assert_eq!(
        tight_ep2.moe_a2a_payload_bytes,
        tight_ep2.moe_a2a_rounds
            * moe_a2a_payload_bytes_per_round(2, EXPERTS as u64, cap, HIDDEN, 4),
        "payload pin at cf = 0.5"
    );
    // cf = 2.0 clamps cap to tokens: no expert can overflow (each token
    // picks an expert at most once), so nothing drops
    let roomy = mk(2, 2.0);
    assert_eq!(roomy.moe_dropped_tokens, 0, "cap = tokens cannot drop");
}

// =========================================================================
// Shape validation: the divisibility contracts fail fast and name terms
// =========================================================================

#[test]
fn ep_misconfigurations_are_rejected_with_targeted_errors() {
    // ep must divide the expert count
    let err = train(&cfg(MOE4, 1, 3, 3, 2, 1, S0, Dtype::F32)).unwrap_err().to_string();
    assert!(err.contains("must divide the bundle's expert count"), "{err}");
    // ep must divide dp
    let err = train(&cfg(MOE4, 1, 3, 2, 2, 1, S0, Dtype::F32)).unwrap_err().to_string();
    assert!(err.contains("EP groups are blocks"), "{err}");
    // ep > 1 needs a MoE bundle
    let err = train(&cfg(DENSE, 1, 2, 2, 2, 1, S0, Dtype::F32)).unwrap_err().to_string();
    assert!(err.contains("needs a MoE bundle"), "{err}");
    // malformed expert grammar never parses
    assert!(BuiltinSpec::parse("builtin:tiny-moe0k1-s2-mb2").is_none());
    assert!(BuiltinSpec::parse("builtin:tiny-moe4k5-s2-mb2").is_none());
}

// =========================================================================
// CLI: --experts/--moe-topk rewrite the bundle and train end to end
// =========================================================================

#[test]
fn cli_experts_flag_trains_and_reports_the_a2a_wire() {
    let out = Command::new(env!("CARGO_BIN_EXE_frontier"))
        .args([
            "train", "--bundle", DENSE, "--experts", "4", "--moe-topk", "2", "--ep", "2",
            "--dp", "2", "--steps", "2", "--microbatches", "2", "--log-every", "0",
        ])
        .output()
        .expect("the frontier binary must launch");
    assert!(
        out.status.success(),
        "CLI MoE smoke failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("MoE a2a"), "the report must print the a2a wire:\n{stdout}");
}

#[test]
fn cli_expert_misuse_dies_with_targeted_errors() {
    let run_cli = |extra: &[&str]| {
        let out = Command::new(env!("CARGO_BIN_EXE_frontier"))
            .args(["train", "--bundle", DENSE, "--dp", "1", "--steps", "1"])
            .args(extra)
            .output()
            .expect("the frontier binary must launch");
        assert!(!out.status.success(), "{extra:?} must be rejected");
        String::from_utf8_lossy(&out.stderr).into_owned()
    };
    let err = run_cli(&["--moe-topk", "2"]);
    assert!(err.contains("--moe-topk needs --experts"), "{err}");
    let err = run_cli(&["--experts", "4", "--moe-topk", "5"]);
    assert!(err.contains("1..=experts"), "{err}");
}

// =========================================================================
// The full grid: ep ∈ {1,2,4} × stage ∈ {0,2,3} × {fp32, bf16}
// (CI: `cargo test --features moe-matrix --test moe moe_matrix`)
// =========================================================================

#[cfg(feature = "moe-matrix")]
mod moe_matrix {
    use super::*;

    /// fp32: every (ep, stage) cell equals its ep = 1 reference bitwise.
    /// bf16: the packed a2a wire quantizes the combine inputs and the
    /// backward's local recompute re-rounds, so ep > 1 tracks ep = 1
    /// within a tolerance instead (the fp32 cells carry the bitwise
    /// contract; the wire-byte pins above stay exact at both widths).
    fn matrix_cell(stage: ShardingStage, precision: Dtype) {
        let reference = run(MOE4, 1, 4, 1, 2, 10, stage, precision);
        assert!(reference.final_loss().is_finite());
        for ep in [2usize, 4] {
            let r = run(MOE4, 1, 4, ep, 2, 10, stage, precision);
            let label = format!("stage {stage} ep{ep} {}", precision.name());
            match precision {
                Dtype::F32 => assert_eq!(
                    traj(&reference),
                    traj(&r),
                    "{label}: must match ep = 1 bitwise"
                ),
                Dtype::Bf16 => {
                    assert_close(&losses(&reference), &losses(&r), 0.05, &label);
                    assert_eq!(r.steps_skipped, 0, "{label}");
                }
            }
            assert_eq!(
                r.moe_a2a_rounds,
                10 * moe_a2a_rounds_per_step(2, 2, 1, 4, ep as u64),
                "{label}: rounds pin holds across the matrix"
            );
        }
    }

    #[test]
    fn moe_matrix_s0_fp32() {
        matrix_cell(S0, Dtype::F32);
    }

    #[test]
    fn moe_matrix_s2_fp32() {
        matrix_cell(S2, Dtype::F32);
    }

    #[test]
    fn moe_matrix_s3_fp32() {
        matrix_cell(S3, Dtype::F32);
    }

    #[test]
    fn moe_matrix_s0_bf16() {
        matrix_cell(S0, Dtype::Bf16);
    }

    #[test]
    fn moe_matrix_s2_bf16() {
        matrix_cell(S2, Dtype::Bf16);
    }

    #[test]
    fn moe_matrix_s3_bf16() {
        matrix_cell(S3, Dtype::Bf16);
    }
}
