//! Vendored API stub of the `xla` crate (PJRT bindings).
//!
//! This container image has no XLA/PJRT toolchain and no crates.io
//! access, so the workspace ships this source-compatible stub instead:
//! the types and signatures `frontier_llm::runtime` compiles against are
//! all here, but [`PjRtClient::cpu`] reports that no PJRT runtime is
//! available.  Every device-side type (`PjRtClient`, `PjRtBuffer`,
//! `PjRtLoadedExecutable`, compiled `XlaComputation`s) is *uninhabited* —
//! it cannot be constructed at runtime — which both documents and
//! enforces that no stubbed compute can silently run.  [`Literal`]s are
//! host-side and fully functional.
//!
//! Swapping in the real crate is a one-line change in
//! `rust/Cargo.toml` (`xla = { path = "vendor/xla" }` -> the real
//! dependency); the engine's builtin backend
//! (`frontier_llm::runtime::builtin`) keeps end-to-end training running
//! either way.

use std::convert::Infallible;
use std::fmt;

/// Error type mirroring `xla::Error` closely enough for `?` conversions.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error(msg.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const NO_RUNTIME: &str = "XLA PJRT runtime is not available in this offline build \
     (vendored stub; use a `builtin:*` bundle or link the real xla crate)";

/// Element types the runtime moves across the host/device boundary.
pub trait NativeType: Copy + 'static {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for u8 {}

// ---------------------------------------------------------------------------
// host-side literals (fully functional)
// ---------------------------------------------------------------------------

/// Typed storage behind a [`Literal`].  Public only so the
/// [`LiteralElement`] conversion trait can name it; not part of the API.
#[doc(hidden)]
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

/// A host tensor (or tuple of tensors) with a shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    payload: Payload,
    dims: Vec<i64>,
}

/// Conversion glue between rust element types and literal payloads
/// (implemented for exactly the element types literals can hold).
pub trait LiteralElement: Sized {
    #[doc(hidden)]
    fn wrap(data: Vec<Self>) -> Payload;
    #[doc(hidden)]
    fn unwrap(lit: &Literal) -> Option<Vec<Self>>;
}

impl LiteralElement for f32 {
    fn wrap(data: Vec<f32>) -> Payload {
        Payload::F32(data)
    }
    fn unwrap(lit: &Literal) -> Option<Vec<f32>> {
        match &lit.payload {
            Payload::F32(d) => Some(d.clone()),
            _ => None,
        }
    }
}

impl LiteralElement for i32 {
    fn wrap(data: Vec<i32>) -> Payload {
        Payload::I32(data)
    }
    fn unwrap(lit: &Literal) -> Option<Vec<i32>> {
        match &lit.payload {
            Payload::I32(d) => Some(d.clone()),
            _ => None,
        }
    }
}

impl LiteralElement for u32 {
    fn wrap(data: Vec<u32>) -> Payload {
        Payload::U32(data)
    }
    fn unwrap(lit: &Literal) -> Option<Vec<u32>> {
        match &lit.payload {
            Payload::U32(d) => Some(d.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: LiteralElement + Clone>(data: &[T]) -> Literal {
        let dims = vec![data.len() as i64];
        Literal { payload: T::wrap(data.to_vec()), dims }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        let have: i64 = self.dims.iter().product();
        if want != have {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { payload: self.payload.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: LiteralElement>(&self) -> Result<Vec<T>> {
        T::unwrap(self).ok_or_else(|| Error::new("literal element type mismatch"))
    }

    pub fn get_first_element<T: LiteralElement>(&self) -> Result<T> {
        let v = self.to_vec::<T>()?;
        v.into_iter().next().ok_or_else(|| Error::new("empty literal"))
    }

    /// Unpack a tuple literal; a non-tuple unpacks to itself (mirrors how
    /// single-output executables behave under `return_tuple=True`).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.payload {
            Payload::Tuple(parts) => Ok(parts.clone()),
            _ => Ok(vec![self.clone()]),
        }
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { payload: Payload::Tuple(parts), dims: vec![] }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

// ---------------------------------------------------------------------------
// device-side types (uninhabited in the stub)
// ---------------------------------------------------------------------------

/// PJRT client handle.  Uninhabited: [`PjRtClient::cpu`] always errors in
/// the stub, so no method body below is ever reachable.
pub struct PjRtClient {
    never: Infallible,
}

impl Clone for PjRtClient {
    fn clone(&self) -> Self {
        match self.never {}
    }
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::new(NO_RUNTIME))
    }

    pub fn platform_name(&self) -> String {
        match self.never {}
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match self.never {}
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        _literal: &Literal,
    ) -> Result<PjRtBuffer> {
        match self.never {}
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        match self.never {}
    }
}

/// Device buffer handle (uninhabited in the stub).
pub struct PjRtBuffer {
    never: Infallible,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match self.never {}
    }
}

/// Compiled executable handle (uninhabited in the stub).
pub struct PjRtLoadedExecutable {
    never: Infallible,
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        match self.never {}
    }
}

/// Parsed HLO module (the stub only carries the source path around).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _path: String,
}

impl HloModuleProto {
    /// The stub refuses at the earliest boundary: artifacts cannot be
    /// compiled without a PJRT runtime anyway.
    pub fn from_text_file(path: &str) -> Result<Self> {
        Err(Error::new(format!("{NO_RUNTIME}; cannot parse {path}")))
    }
}

/// Computation wrapper (constructible, but never compilable in the stub).
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { _proto: proto.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let t = Literal::tuple(vec![l.clone(), Literal::vec1(&[7i32])]);
        assert_eq!(t.to_tuple().unwrap().len(), 2);
        assert_eq!(l.to_tuple().unwrap().len(), 1);
    }

    #[test]
    fn no_runtime_available() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
