//! Vendored minimal re-implementation of the `anyhow` API surface this
//! workspace uses.  The build is fully offline (no crates.io access — see
//! `.cargo/config.toml` at the workspace root), so instead of the real
//! crate we ship this drop-in subset: `Error`, `Result`, `Context`,
//! `anyhow!`, `bail!`, `ensure!`.
//!
//! Semantics match the real crate where it matters here:
//! * `Error` does NOT implement `std::error::Error` (that is what makes
//!   the blanket `From<E: std::error::Error>` conversion coherent);
//! * `Display` shows the outermost context, `{:?}` shows the whole chain
//!   in `Caused by:` form;
//! * `Context` works on `Result<T, E: std::error::Error>`, on
//!   `Result<T, Error>` and on `Option<T>`.

use std::fmt::{self, Debug, Display};

/// Error value: a chain of context frames, innermost first.
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { frames: vec![message.to_string()] }
    }

    /// Attach an outer context frame (what `Context::context` defers to).
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.frames.push(context.to_string());
        self
    }

    /// Context frames, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().rev().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.to_string_outer())
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut frames = self.frames.iter().rev();
        if let Some(top) = frames.next() {
            write!(f, "{top}")?;
        }
        let mut first = true;
        for frame in frames {
            if first {
                write!(f, "\n\nCaused by:")?;
                first = false;
            }
            write!(f, "\n    {frame}")?;
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // flatten the std source chain into frames (innermost first)
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.insert(0, s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::Error;

    /// Sealed conversion trait so `Context` can accept both plain std
    /// errors and `Error` itself without overlapping impls.
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Attach context to fallible values (`Result` / `Option`).
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formatted message, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition is violated.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err()).context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
        assert!(dbg.contains("missing thing"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(f(7).unwrap_err().to_string(), "unlucky");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let from_value = anyhow!(String::from("owned message"));
        assert_eq!(from_value.to_string(), "owned message");
    }

    #[test]
    fn context_on_error_and_option() {
        let base: Result<()> = Err(anyhow!("base"));
        let e = base.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.chain().count(), 2);
        let n: Option<u32> = None;
        assert!(n.context("absent").is_err());
        let s: Option<u32> = Some(1);
        assert_eq!(s.with_context(|| "unused").unwrap(), 1);
    }
}
