//! Prints every static table of the paper (I, II, V), the Fig 5 bandwidth
//! matrix, the Fig 11 throughput comparison, and the Fig 12/13 scaling
//! studies — the "everything at a glance" reproduction report.
//!
//!   cargo run --release --offline --example paper_tables

use frontier_llm::config::{self, ParallelConfig};
use frontier_llm::mem;
use frontier_llm::metrics::{weak_scaling_efficiency, Csv};
use frontier_llm::perf::PerfModel;
use frontier_llm::topology::Machine;

fn main() -> anyhow::Result<()> {
    let perf = PerfModel::default();

    println!("== Table I: GPT architecture zoo ==");
    println!(
        "{:>6} {:>8} {:>8} {:>7} {:>13} {:>13}",
        "model", "layers", "hidden", "heads", "12Ld^2", "exact params"
    );
    for m in config::paper_zoo() {
        println!(
            "{:>6} {:>8} {:>8} {:>7} {:>13.3e} {:>13.3e}",
            m.name, m.n_layers, m.hidden, m.n_heads,
            m.paper_params() as f64, m.total_params() as f64
        );
    }

    println!("\n== Table II: minimum training memory (fp16 + fp32 Adam) ==");
    println!(
        "{:>6} {:>12} {:>12} {:>12} {:>12}  paper",
        "model", "params(6x)", "grads(4x)", "optim(4x)", "total(14x)"
    );
    for (name, n, paper) in [
        ("22B", 22e9 as u64, "308 GB"),
        ("175B", 175e9 as u64, "2.45 TB"),
        ("1T", 1_000_000_000_000, "14 TB"),
    ] {
        let (p, g, o, t) = mem::table2_row(n);
        let gb = |b: u64| format!("{:.0} GB", b as f64 / 1e9);
        println!(
            "{:>6} {:>12} {:>12} {:>12} {:>12}  {paper}",
            name, gb(p), gb(g), gb(o), gb(t)
        );
    }

    println!("\n== Fig 5: link bandwidth matrix (GB/s), node 0 + first GPU of node 1 ==");
    let machine = Machine::new(2);
    print!("      ");
    for j in 0..9 {
        print!("{j:>5}");
    }
    println!();
    for (i, row) in machine.bandwidth_matrix(9).iter().enumerate() {
        print!("GPU{i:<2} ");
        for b in row {
            print!("{b:>5.0}");
        }
        println!();
    }
    println!("(200 intra-card, 100 adjacent cards, 50 far cards, 25 inter-node)");

    println!("\n== Table V + Fig 11: tuned recipes and achieved throughput ==");
    println!(
        "{:>6} {:>3} {:>3} {:>4} {:>6} {:>5} {:>6} {:>9} {:>9} {:>9}",
        "model", "TP", "PP", "MBS", "GBS", "GPUs", "ZeRO", "paper", "model", "delta"
    );
    let mut fig11 = Csv::new(&["model", "paper_pct", "model_pct", "paper_tflops", "model_tflops"]);
    for (r, paper_pct, paper_tflops) in config::fig11_recipes() {
        let b = perf.evaluate(&r.model, &r.parallel).expect("recipe evaluates");
        println!(
            "{:>6} {:>3} {:>3} {:>4} {:>6} {:>5} {:>6} {:>8.2}% {:>8.2}% {:>+8.2}",
            r.model.name,
            r.parallel.tp,
            r.parallel.pp,
            r.parallel.mbs,
            r.parallel.gbs,
            r.gpus(),
            r.parallel.zero_stage.index(),
            paper_pct,
            b.pct_peak,
            b.pct_peak - paper_pct
        );
        fig11.row(&[
            r.model.name.clone(),
            paper_pct.to_string(),
            format!("{:.2}", b.pct_peak),
            paper_tflops.to_string(),
            format!("{:.1}", b.tflops_per_gpu),
        ]);
    }
    fig11.write("results/fig11_throughput.csv")?;

    // §V.B roofline: arithmetic intensity
    for (r, _, _) in config::fig11_recipes().into_iter().take(2) {
        let b = perf.evaluate(&r.model, &r.parallel).unwrap();
        println!(
            "   {} arithmetic intensity: {:.0} flops/byte (paper: 180+, compute-bound)",
            r.model.name, b.arithmetic_intensity
        );
    }

    // ---- Fig 12: weak scaling ----
    println!("\n== Fig 12: weak scaling (per-replica GBS fixed) ==");
    let mut fig12 = Csv::new(&["model", "gpus", "samples_per_sec", "efficiency_pct"]);
    for (name, points) in [("175b", vec![128u32, 256, 512, 1024]), ("1t", vec![512, 1024, 2048, 3072])] {
        let recipe = if name == "175b" { config::recipe_175b() } else { config::recipe_1t() };
        let per_replica = recipe.parallel.gpus_per_replica();
        let gbs_rep = recipe.parallel.gbs / recipe.parallel.dp;
        let mut base: Option<(u32, f64)> = None;
        println!("  {name} (GBS/replica = {gbs_rep}):");
        for gpus in points {
            let dp = gpus / per_replica;
            if dp == 0 {
                continue;
            }
            let cfg = recipe.parallel.clone().with_dp(dp).with_gbs(gbs_rep * dp);
            let sps = perf.samples_per_sec(&recipe.model, &cfg).unwrap();
            let eff = base.map(|b| weak_scaling_efficiency(b, (gpus, sps))).unwrap_or(100.0);
            if base.is_none() {
                base = Some((gpus, sps));
            }
            println!("    {gpus:>5} GPUs: {sps:>8.2} samples/s  eff {eff:>6.2}%  (paper: 100%)");
            fig12.row(&[name.into(), gpus.to_string(), format!("{sps:.3}"), format!("{eff:.2}")]);
        }
    }
    fig12.write("results/fig12_weak.csv")?;

    // ---- Fig 13: strong scaling ----
    println!("\n== Fig 13: strong scaling (total GBS fixed) ==");
    let mut fig13 = Csv::new(&["model", "gpus", "samples_per_sec", "efficiency_pct"]);
    for (name, gbs, points, paper_eff) in [
        ("175b", 8000u32, vec![128u32, 256, 512, 1024], 89.93),
        ("1t", 8016, vec![512, 1024, 2048, 3072], 87.05),
    ] {
        let recipe = if name == "175b" { config::recipe_175b() } else { config::recipe_1t() };
        let per_replica = recipe.parallel.gpus_per_replica();
        let mut base: Option<(u32, f64)> = None;
        println!("  {name} (total GBS = {gbs}):");
        let mut last_eff = 100.0;
        for gpus in points {
            let dp = gpus / per_replica;
            if dp == 0 {
                continue;
            }
            let adj_gbs = (gbs / dp) * dp; // keep divisible
            let cfg = recipe.parallel.clone().with_dp(dp).with_gbs(adj_gbs);
            let sps = perf.samples_per_sec(&recipe.model, &cfg).unwrap();
            let eff = base.map(|b| weak_scaling_efficiency(b, (gpus, sps))).unwrap_or(100.0);
            if base.is_none() {
                base = Some((gpus, sps));
            }
            last_eff = eff;
            println!("    {gpus:>5} GPUs: {sps:>8.2} samples/s  eff {eff:>6.2}%");
            fig13.row(&[name.into(), gpus.to_string(), format!("{sps:.3}"), format!("{eff:.2}")]);
        }
        println!("    (paper strong-scaling efficiency at max GPUs: {paper_eff}%; ours: {last_eff:.2}%)");
    }
    fig13.write("results/fig13_strong.csv")?;

    println!("\nwrote results/fig11_throughput.csv, fig12_weak.csv, fig13_strong.csv");
    Ok(())
}
