//! End-to-end training driver (DESIGN.md experiment "E2E").
//!
//! Trains a real GPT on the synthetic Markov corpus through the full
//! stack — 1F1B pipeline stages executing AOT-compiled JAX/Pallas
//! graphs, DP gradient sync through the ring collectives, ZeRO-1 sharded
//! Adam — and logs the loss curve to `results/e2e_loss.csv`.
//!
//! Default: ~10M-parameter GPT (2 stages x dp2), a few hundred steps.
//! `--large` switches to the ~124M-parameter GPT-2-small shape
//! (gpt-125m, 4 stages) for a shorter demonstration run — one CPU core
//! stands in for Frontier here, so large runs are budgeted in steps.
//!
//!   cargo run --release --offline --example train_e2e -- \
//!       [--steps N] [--dp N] [--microbatches N] [--large] [--zero-stage 0|1|2|3] \
//!       [--bundle builtin:tiny-moe4k2-s2-mb2 --ep N --capacity-factor F]

use frontier_llm::config::ScheduleKind;
use frontier_llm::coordinator::{train, EngineConfig};
use frontier_llm::metrics::Csv;
use frontier_llm::optim::{AdamConfig, LrSchedule};
use frontier_llm::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let large = args.flag("large");

    let (bundle, default_steps, default_dp) = if large {
        ("gpt-125m-s4-mb1", 30u32, 1usize)
    } else {
        ("gpt-10m-s2-mb1", 300u32, 2usize)
    };
    let steps: u32 = args.opt("steps", default_steps).map_err(anyhow::Error::msg)?;
    let dp: usize = args.opt("dp", default_dp).map_err(anyhow::Error::msg)?;
    let microbatches: u32 = args.opt("microbatches", 4).map_err(anyhow::Error::msg)?;

    let cfg = EngineConfig {
        bundle: args.opt_str("bundle", bundle),
        artifacts_root: args.opt_str("artifacts", "artifacts").into(),
        dp,
        precision: {
            let name = args.opt_str("precision", "fp32");
            frontier_llm::precision::Dtype::parse(&name)
                .ok_or_else(|| anyhow::anyhow!("--precision must be fp32|bf16, got {name:?}"))?
        },
        loss_scale_init: args.opt("loss-scale", 1.0f32).map_err(anyhow::Error::msg)?,
        loss_scale_growth_interval: args
            .opt("loss-scale-growth", 0u32)
            .map_err(anyhow::Error::msg)?,
        tp: args.opt("tp", 1).map_err(anyhow::Error::msg)?,
        // expert parallelism (builtin:*-moe* bundles): --ep N shards the
        // expert compute over blocks of N consecutive DP replicas through
        // the deterministic all_to_all; --capacity-factor bounds each
        // expert's per-microbatch token slots (GShard default 1.25)
        ep: args.opt("ep", 1).map_err(anyhow::Error::msg)?,
        capacity_factor: args.opt("capacity-factor", 1.25f32).map_err(anyhow::Error::msg)?,
        schedule: ScheduleKind::OneF1B,
        microbatches,
        steps,
        adam: AdamConfig { lr: 6e-4, weight_decay: 0.01, ..Default::default() },
        lr_schedule: Some(LrSchedule {
            warmup_steps: (steps / 20).max(2) as u64,
            total_steps: steps as u64,
            min_ratio: 0.1,
        }),
        zero_stage: {
            use frontier_llm::zero::ShardingStage;
            match args.get("zero-stage") {
                Some(s) => ShardingStage::parse(s)
                    .ok_or_else(|| anyhow::anyhow!("--zero-stage must be 0|1|2|3, got {s:?}"))?,
                // legacy default: shard optimizer states whenever there is
                // a DP group to shard across (--zero1 stays as the alias)
                None if args.flag("zero1") || dp > 1 => ShardingStage::OptimizerStates,
                None => ShardingStage::Ddp,
            }
        },
        overlap_grad_sync: !args.flag("no-overlap"),
        // --nodes N packs the world onto N simulated Frontier nodes and
        // runs the sharded-DP collectives hierarchically (two-tier);
        // --grad-wire int8 quantizes the inter-node gradient hop
        nodes: args.opt("nodes", 0u32).map_err(anyhow::Error::msg)?,
        grad_wire: match args.get("grad-wire") {
            Some(s) => Some(frontier_llm::precision::GradWire::parse(s).ok_or_else(|| {
                anyhow::anyhow!("--grad-wire must be fp32|bf16|int8, got {s:?}")
            })?),
            None => None,
        },
        zero3_prefetch: args.opt("zero3-prefetch", 1usize).map_err(anyhow::Error::msg)?,
        seed: args.opt("seed", 1234).map_err(anyhow::Error::msg)?,
        log_every: args.opt("log-every", 10).map_err(anyhow::Error::msg)?,
        checkpoint_dir: args.get("checkpoint").map(Into::into),
        checkpoint_every: args.opt("checkpoint-every", 0).map_err(anyhow::Error::msg)?,
        resume: args.flag("resume"),
        // crash-consistent checkpointing: --async-checkpoint persists on a
        // background saver thread; --ckpt-keep retains a generation chain
        async_checkpoint: args.flag("async-checkpoint"),
        ckpt_keep: args.opt("ckpt-keep", 2usize).map_err(anyhow::Error::msg)?,
        // elastic knobs: `--fault kill@STEP:RANK,...` injects deterministic
        // faults (kill / join / ckpt-crash / write-fail); bounded collective
        // waits surface the dead peer and the run recovers at dp∓1 from the
        // last committed checkpoint generation
        comm_timeout_ms: args.opt("comm-timeout-ms", 10_000u64).map_err(anyhow::Error::msg)?,
        faults: match args.get("fault") {
            Some(s) => frontier_llm::coordinator::FaultSpec::parse_list(s)
                .map_err(anyhow::Error::msg)?,
            None => Vec::new(),
        },
        // observability: --trace-out writes the merged Chrome trace,
        // --metrics-jsonl streams one JSON object per logged step; either
        // flag also arms the measured-vs-predicted audit table below
        trace_out: args.get("trace-out").map(Into::into),
        metrics_jsonl: args.get("metrics-jsonl").map(Into::into),
        ..Default::default()
    };

    println!(
        "e2e: bundle={} dp={} m={} steps={} zero-stage={}",
        cfg.bundle, cfg.dp, cfg.microbatches, cfg.steps, cfg.zero_stage
    );
    let report = train(&cfg)?;

    // ---- loss curve to CSV ----
    let mut csv = Csv::new(&["step", "loss", "grad_norm", "step_time_s"]);
    for l in &report.logs {
        csv.rowf(&[l.step as f64, l.loss as f64, l.grad_norm as f64, l.step_time_s]);
    }
    let out = format!("results/e2e_loss_{}.csv", cfg.bundle);
    csv.write(&out)?;

    // ---- summary (EXPERIMENTS.md §E2E records this) ----
    let first = report.initial_loss();
    let last_k: Vec<f32> = report
        .logs
        .iter()
        .rev()
        .take(10)
        .map(|l| l.loss)
        .collect();
    let tail_mean = last_k.iter().sum::<f32>() / last_k.len() as f32;
    println!("\n=== E2E SUMMARY ===");
    print!("{}", report.render_summary());

    // ---- divergence audit: span-measured vs PerfModel-predicted ----
    // The predicted column prices Frontier MI250X hardware while the
    // measured column is this host's CPU simulation, so absolute ms
    // differ by construction; the audit is about which terms dominate
    // and whether the dimensionless fractions (dp overlap, pipeline
    // bubble) agree between the trace and the engine/analytic forms.
    if let Some(ts) = &report.trace_summary {
        use frontier_llm::config::{ModelSpec, ParallelConfig};
        use frontier_llm::perf::PerfModel;
        use frontier_llm::runtime::builtin::BuiltinSpec;
        let (predicted, analytic_bubble) = match BuiltinSpec::parse(&cfg.bundle) {
            Some(b) => {
                let v = cfg.schedule.chunks();
                let pcfg = ParallelConfig {
                    tp: cfg.tp as u32,
                    pp: b.n_stages as u32 / v,
                    dp: cfg.dp as u32,
                    mbs: b.mbs as u32,
                    gbs: b.mbs as u32 * cfg.microbatches * cfg.dp as u32,
                    zero_stage: cfg.zero_stage,
                    schedule: cfg.schedule,
                    experts: b.experts as u32,
                    moe_topk: b.topk as u32,
                    ep: cfg.ep as u32,
                    capacity_factor: cfg.capacity_factor,
                    ..ParallelConfig::default()
                };
                let model = ModelSpec::new(
                    &b.name,
                    b.n_stages as u32,
                    b.hidden as u64,
                    1,
                    b.vocab as u64,
                    b.seq as u64,
                );
                let bubble =
                    pcfg.validate().is_ok().then(|| pcfg.bubble_fraction());
                let bd = PerfModel::new()
                    .with_dp_overlap(report.dp_overlap_fraction())
                    .evaluate(&model, &pcfg)
                    .ok();
                (bd, bubble)
            }
            None => (None, None),
        };
        println!("\n=== TRACE AUDIT (measured vs predicted) ===");
        let rows = frontier_llm::trace::audit(
            ts,
            predicted.as_ref(),
            analytic_bubble,
            Some(report.dp_overlap_fraction()),
        );
        print!("{}", frontier_llm::trace::render_audit(&rows));
    }

    println!("loss              : {first:.4} -> {tail_mean:.4} (tail-10 mean)");
    println!("loss curve        : {out}");
    assert!(
        tail_mean < first,
        "loss must descend over the run ({first} -> {tail_mean})"
    );
    Ok(())
}
