//! Regenerates the §III empirical-analysis figures (6, 7, 8) as CSV files
//! plus terminal tables, and checks the four Observations hold.
//!
//!   cargo run --release --offline --example frontier_sweep

use frontier_llm::config::{lookup, ParallelConfig};
use frontier_llm::metrics::Csv;
use frontier_llm::perf::PerfModel;

fn main() -> anyhow::Result<()> {
    let perf = PerfModel::default();

    // ---- Fig 6: throughput vs TP (1.4B on 8 GPUs) ----
    println!("Fig 6 — GPU throughput vs TP (1.4B, 8 GPUs)");
    let m14 = lookup("1.4b").unwrap();
    let mut fig6 = Csv::new(&["tp", "tflops_per_gpu", "pct_peak"]);
    let mut prev = f64::INFINITY;
    for tp in [1u32, 2, 4, 8] {
        let cfg = ParallelConfig::default()
            .with_tp(tp)
            .with_dp(8 / tp)
            .with_gbs(64)
            .with_mbs(4);
        let b = perf.evaluate(&m14, &cfg).unwrap();
        println!("  TP={tp}: {:6.1} TFLOPS/GPU ({:5.2}%)", b.tflops_per_gpu, b.pct_peak);
        fig6.rowf(&[tp as f64, b.tflops_per_gpu, b.pct_peak]);
        assert!(b.pct_peak < prev, "Obs III.1 violated at TP={tp}");
        prev = b.pct_peak;
    }
    fig6.write("results/fig6_tp.csv")?;
    println!("  [Obs III.1 holds: larger TP deteriorates training performance]\n");

    // ---- Fig 7: throughput vs GBS (22B and 1T) ----
    println!("Fig 7 — GPU throughput vs global batch size");
    let mut fig7 = Csv::new(&["model", "gbs", "tflops_per_gpu", "pct_peak"]);
    for (name, tp, pp, gbs_list, zero1) in [
        ("22b", 2u32, 8u32, vec![8u32, 16, 32, 64, 128, 256], false),
        ("1t", 8, 64, vec![64, 128, 256, 512, 1024, 1600], true),
    ] {
        let model = lookup(name).unwrap();
        println!("  {name} (tp{tp} pp{pp}):");
        let mut prev = 0.0;
        for gbs in gbs_list {
            let cfg = ParallelConfig::default()
                .with_tp(tp)
                .with_pp(pp)
                .with_gbs(gbs)
                .with_zero1(zero1);
            let b = perf.evaluate(&model, &cfg).unwrap();
            println!("    GBS={gbs:>4}: {:6.1} TFLOPS/GPU ({:5.2}%)", b.tflops_per_gpu, b.pct_peak);
            fig7.row(&[
                name.to_string(),
                gbs.to_string(),
                format!("{}", b.tflops_per_gpu),
                format!("{}", b.pct_peak),
            ]);
            assert!(b.pct_peak > prev, "Obs III.2 violated at {name} GBS={gbs}");
            prev = b.pct_peak;
        }
    }
    fig7.write("results/fig7_gbs.csv")?;
    println!("  [Obs III.2 holds: larger GBS saturates the pipeline]\n");

    // ---- Fig 8a: throughput vs PP at fixed GBS ----
    println!("Fig 8a — throughput vs PP, GBS fixed at 128 (175B, tp8)");
    let m175 = lookup("175b").unwrap();
    let mut fig8a = Csv::new(&["pp", "tflops_per_gpu", "pct_peak"]);
    let mut prev = f64::INFINITY;
    for pp in [8u32, 12, 16, 24, 32] {
        let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(128);
        let b = perf.evaluate(&m175, &cfg).unwrap();
        println!("  PP={pp:>2}: {:6.1} TFLOPS/GPU ({:5.2}%)", b.tflops_per_gpu, b.pct_peak);
        fig8a.rowf(&[pp as f64, b.tflops_per_gpu, b.pct_peak]);
        assert!(b.pct_peak < prev, "Obs III.3 violated at PP={pp}");
        prev = b.pct_peak;
    }
    fig8a.write("results/fig8a_pp_fixed.csv")?;
    println!("  [Obs III.3 holds: deeper pipeline at fixed GBS loses throughput]\n");

    // ---- Fig 8b: throughput vs PP with GBS scaled (bubble ratio fixed) ----
    println!("Fig 8b — throughput vs PP, GBS scaled with PP (175B, tp8)");
    let mut fig8b = Csv::new(&["pp", "gbs", "tflops_per_gpu", "pct_peak"]);
    let mut series = Vec::new();
    for (pp, gbs) in [(8u32, 128u32), (12, 192), (16, 256), (24, 384), (32, 512)] {
        let cfg = ParallelConfig::default().with_tp(8).with_pp(pp).with_gbs(gbs);
        let b = perf.evaluate(&m175, &cfg).unwrap();
        println!(
            "  PP={pp:>2} GBS={gbs:>3}: {:6.1} TFLOPS/GPU ({:5.2}%)",
            b.tflops_per_gpu, b.pct_peak
        );
        fig8b.rowf(&[pp as f64, gbs as f64, b.tflops_per_gpu, b.pct_peak]);
        series.push(b.pct_peak);
    }
    fig8b.write("results/fig8b_pp_scaled.csv")?;
    let base = series[0];
    assert!(
        series.iter().all(|s| (s - base).abs() / base < 0.10),
        "Obs III.4 violated: {series:?}"
    );
    println!("  [Obs III.4 holds: fixed PP/M ratio maintains throughput]\n");

    println!("wrote results/fig6_tp.csv, fig7_gbs.csv, fig8a_pp_fixed.csv, fig8b_pp_scaled.csv");
    Ok(())
}
