//! Quickstart: the whole system in 60 seconds.
//!
//! 1. Train a tiny GPT for a handful of steps through the REAL engine
//!    (2-stage 1F1B pipeline x 2-way data parallel, ZeRO-1 sharded Adam,
//!    AOT-compiled JAX/Pallas stage executables on PJRT).
//! 2. Re-run it tensor-parallel (`tp = 2`): every builtin stage sharded
//!    Megatron-style, per-layer all-reduces through real collectives —
//!    same loss trajectory, twice the workers.
//! 3. Ask the calibrated performance model what the paper's 175B recipe
//!    achieves on Frontier.
//!
//! Run with: `cargo run --release --offline --example quickstart`
//! (after `make artifacts`).

use frontier_llm::config::{recipe_175b, ScheduleKind};
use frontier_llm::coordinator::{train, EngineConfig, FaultSpec};
use frontier_llm::optim::AdamConfig;
use frontier_llm::perf::PerfModel;
use frontier_llm::zero::ShardingStage;

fn main() -> anyhow::Result<()> {
    // ---- 1. real training through the engine ----
    // AOT artifacts when present; otherwise the pure-Rust builtin stages
    // (same coordinator, schedules, collectives, ZeRO-1 — zero setup)
    let have_artifacts = std::path::Path::new("artifacts/tiny-s2-mb2/meta.json").exists();
    let (bundle, lr) = if have_artifacts {
        ("tiny-s2-mb2", 1e-3f32)
    } else {
        println!("(no AOT artifacts found — using the builtin reference stages)");
        ("builtin:tiny-s2-mb2", 2e-2f32)
    };
    println!("== training tiny model (2-stage pipeline x dp2, ZeRO-1) ==");
    let report = train(&EngineConfig {
        bundle: bundle.into(),
        dp: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 15,
        zero_stage: ShardingStage::OptimizerStates,
        adam: AdamConfig { lr, ..Default::default() },
        log_every: 5,
        ..Default::default()
    })?;
    // one shared summary block renders the run (the `train` CLI and
    // `train_e2e` print the same `TrainReport::render_summary`): loss,
    // throughput, measured dp-overlap, precision/loss-scale state, and
    // the ZeRO wire/residency counters.  Knobs behind those lines:
    // `overlap_grad_sync`/`grad_bucket_floats`/`collective_algo` (DP
    // sync), `precision: Dtype::Bf16` (mixed precision), `zero_stage:
    // ShardingStage::Gradients`/`::Parameters` (ZeRO-2/3 dataflow).
    print!("{}", report.render_summary());
    println!();
    assert!(report.final_loss() < report.initial_loss(), "loss must decrease");

    // ---- 2. the same run, tensor-parallel (§II.B executed for real) ----
    // TP shards builtin stages only, so this leg always runs the
    // pure-Rust reference backend (equivalent numerics either way)
    println!("== same model, tp=2 x pp=2 x dp=2 (Megatron-sharded stages) ==");
    let tp_report = train(&EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp: 2,
        tp: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 15,
        zero_stage: ShardingStage::OptimizerStates,
        adam: AdamConfig { lr: 2e-2, ..Default::default() },
        log_every: 5,
        ..Default::default()
    })?;
    println!(
        "loss {:.3} -> {:.3} on {} simulated GCDs; {} TP all-reduce rounds, {:.1} KB reduced\n",
        tp_report.initial_loss(),
        tp_report.final_loss(),
        tp_report.world_size,
        tp_report.tp_ar_rounds,
        tp_report.tp_ar_bytes as f64 / 1e3,
    );
    assert!(tp_report.final_loss() < tp_report.initial_loss());

    // ---- 2.5 topology-aware: the same run packed onto 2 Frontier nodes ----
    // `nodes: 2` switches the sharded-DP collectives onto the two-tier
    // (intra-node / Slingshot) path — same trajectory bitwise at fp32 —
    // and ZeRO-3 serves repeat gathers from node-local secondary
    // partitions; `grad_wire: Int8` quantizes the inter-node grad hop
    println!("== same model on 2 simulated nodes (hierarchical collectives, zero3) ==");
    let hier_report = train(&EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 15,
        zero_stage: ShardingStage::Parameters,
        adam: AdamConfig { lr: 2e-2, ..Default::default() },
        log_every: 5,
        nodes: 2,
        ..Default::default()
    })?;
    println!(
        "loss {:.3} -> {:.3}; grad sync {:.1} KB intra-node / {:.1} KB inter-node, \
         param AG {:.1} KB intra / {:.1} KB inter (secondary partitions serve repeats), \
         pp p2p {:.1} KB intra / {:.1} KB inter\n",
        hier_report.initial_loss(),
        hier_report.final_loss(),
        hier_report.dp_bucket_intra_bytes as f64 / 1e3,
        hier_report.dp_bucket_inter_bytes as f64 / 1e3,
        hier_report.dp_param_ag_intra_bytes as f64 / 1e3,
        hier_report.dp_param_ag_inter_bytes as f64 / 1e3,
        hier_report.pp_p2p_intra_bytes as f64 / 1e3,
        hier_report.pp_p2p_inter_bytes as f64 / 1e3,
    );
    assert!(hier_report.final_loss() < hier_report.initial_loss());

    // ---- 2.75 elastic: kill a worker mid-run, recover at dp − 1 ----
    // `kill@3:1` takes world rank 1 down at the top of step 3; bounded
    // collective waits surface the loss (PeerLost) instead of hanging,
    // and the coordinator restarts from the last *committed* checkpoint
    // generation at dp = 1, re-partitioning the ZeRO optimizer shards —
    // at most `checkpoint_every` steps are recomputed.  Saves here run
    // asynchronously: each rank snapshots its state at the barrier and a
    // background saver thread persists + atomically commits gen-<step>/
    // while training continues (same bytes as sync saves, bitwise)
    println!("== same model with a mid-run worker kill (elastic recovery) ==");
    let ckpt = std::env::temp_dir().join(format!("fllm-quickstart-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);
    let elastic_report = train(&EngineConfig {
        bundle: "builtin:tiny-s2-mb2".into(),
        dp: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 15,
        zero_stage: ShardingStage::OptimizerStates,
        adam: AdamConfig { lr: 2e-2, ..Default::default() },
        log_every: 5,
        checkpoint_dir: Some(ckpt.clone()),
        checkpoint_every: 2,
        async_checkpoint: true,
        faults: FaultSpec::parse_list("kill@3:1").expect("static fault list parses"),
        comm_timeout_ms: 2000,
        ..Default::default()
    })?;
    std::fs::remove_dir_all(&ckpt).ok();
    println!(
        "loss {:.3} -> {:.3}: {} recovery event(s), {} step(s) lost and recomputed, \
         finished on {} GCDs",
        elastic_report.initial_loss(),
        elastic_report.final_loss(),
        elastic_report.recovery_events,
        elastic_report.lost_steps,
        elastic_report.world_size,
    );
    println!(
        "ckpt saves: {:.2} ms exposed to the step loop, {:.2} ms hidden on the saver thread\n",
        elastic_report.ckpt_save_exposed_ms, elastic_report.ckpt_save_hidden_ms,
    );
    assert_eq!(elastic_report.recovery_events, 1, "the injected kill must trigger recovery");
    assert!(elastic_report.final_loss() < elastic_report.initial_loss());

    // ---- 2.9 expert-parallel: a MoE bundle routed over all_to_all ----
    // `-moe4k2` gives every stage block 4 expert MLPs behind a
    // deterministic top-2 gate; `ep: 2` shards the expert *compute* over
    // pairs of DP replicas through the dtype-packed all_to_all (expert
    // parameters stay DP-replicated, so the trajectory is bitwise the
    // ep = 1 run at fp32 — swap `ep: 1` in to check)
    println!("== 4-expert top-2 MoE, expert-parallel over 2 replicas ==");
    let moe_report = train(&EngineConfig {
        bundle: "builtin:tiny-moe4k2-s2-mb2".into(),
        dp: 2,
        ep: 2,
        schedule: ScheduleKind::OneF1B,
        microbatches: 4,
        steps: 15,
        zero_stage: ShardingStage::OptimizerStates,
        adam: AdamConfig { lr: 2e-2, ..Default::default() },
        log_every: 5,
        ..Default::default()
    })?;
    println!(
        "loss {:.3} -> {:.3}; a2a wire: {} rounds, {:.1} KB routed payload, \
         {} token(s) dropped at capacity (cf 1.25)\n",
        moe_report.initial_loss(),
        moe_report.final_loss(),
        moe_report.moe_a2a_rounds,
        moe_report.moe_a2a_payload_bytes as f64 / 1e3,
        moe_report.moe_dropped_tokens,
    );
    assert!(moe_report.moe_a2a_rounds > 0, "ep = 2 must route over the wire");
    assert!(moe_report.final_loss() < moe_report.initial_loss());

    // ---- 3. the paper's 175B recipe through the performance model ----
    println!("== paper Table V, 175B recipe on simulated Frontier ==");
    let r = recipe_175b();
    let b = PerfModel::default().evaluate(&r.model, &r.parallel).expect("recipe runs");
    println!(
        "TP={} PP={} DP={} on {} GPUs: {:.1} TFLOPS/GPU = {:.2}% of peak \
         (paper measured 36.14%)",
        r.parallel.tp,
        r.parallel.pp,
        r.parallel.dp,
        r.gpus(),
        b.tflops_per_gpu,
        b.pct_peak
    );
    println!(
        "step breakdown: compute {:.1}s + tp-comm {:.1}s + bubble {:.1}s + dp {:.2}s",
        b.t_compute, b.t_tp_comm, b.t_bubble, b.t_dp_comm
    );
    Ok(())
}
