//! Reproduces the §IV DeepHyper study: Bayesian HPO over the Table IV
//! space with OOM-failure penalties (Fig 9) and the SHAP sensitivity
//! ranking (Fig 10).  Writes `results/fig9_trajectory.csv` and
//! `results/fig10_shap.csv`.
//!
//!   cargo run --release --offline --example hpo_search -- [--evals N] [--seed N]

use frontier_llm::hpo::{self, SearchConfig};
use frontier_llm::metrics::Csv;
use frontier_llm::perf::PerfModel;
use frontier_llm::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let evals: u32 = args.opt("evals", 160).map_err(anyhow::Error::msg)?;
    let seed: u64 = args.opt("seed", 7).map_err(anyhow::Error::msg)?;

    let perf = PerfModel::default();
    println!("Fig 9 — DeepHyper-style search over Table IV ({evals} evaluations)");
    let result = hpo::run_search(&perf, &SearchConfig { n_evals: evals, seed, ..Default::default() });

    let mut csv = Csv::new(&[
        "eval", "pp", "tp", "mbs", "gas", "zero_stage", "nnodes", "interleave",
        "objective_tflops", "failed", "best_so_far",
    ]);
    for (i, ev) in result.evals.iter().enumerate() {
        csv.row(&[
            i.to_string(),
            ev.point.pp.to_string(),
            ev.point.tp.to_string(),
            ev.point.mbs.to_string(),
            ev.point.gas.to_string(),
            ev.point.zero_stage.index().to_string(),
            ev.point.nnodes.to_string(),
            ev.point.interleave.to_string(),
            ev.objective.map(|v| format!("{v:.2}")).unwrap_or_default(),
            (ev.objective.is_none() as u8).to_string(),
            format!("{:.2}", result.best_trajectory[i]),
        ]);
    }
    csv.write("results/fig9_trajectory.csv")?;

    let fails = result.failures_by_quarter();
    println!("  evaluations : {}", result.evals.len());
    println!("  failures    : {} total, by quarter {fails:?}", result.n_failures());
    println!("  (paper: failures mostly OOM, frequency decreasing over time)");
    let best = result.best().expect("search must find a feasible config");
    println!(
        "  best        : pp{} tp{} mbs{} gas{} zero-stage={} nodes{} -> {:.1} TFLOPS/GPU",
        best.point.pp,
        best.point.tp,
        best.point.mbs,
        best.point.gas,
        best.point.zero_stage,
        best.point.nnodes,
        best.objective.unwrap()
    );
    println!("  (paper Fig 9 reaches 22 TFLOPS on its 175B/16-node jobs)\n");

    // ---- Fig 10: SHAP sensitivity ----
    println!("Fig 10 — hyper-parameter sensitivity (mean |SHAP| on TFLOPS)");
    let ranking = hpo::shap_ranking(&result, 96);
    let mut csv = Csv::new(&["feature", "mean_abs_shap"]);
    for (name, v) in &ranking {
        println!("  {name:<12} {v:>8.3}");
        csv.row(&[name.clone(), format!("{v}")]);
    }
    csv.write("results/fig10_shap.csv")?;
    println!(
        "  (paper ranking: mbs > tp > pp > num_nodes > zero_stage; ours: {})",
        ranking.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>().join(" > ")
    );
    Ok(())
}
